"""Public dispatch layer over the kernel implementations.

Each op is registered in a (op, mode) table with up to three execution
modes (DESIGN.md §7):

  'pallas'     — the fused Pallas TPU kernels. On non-TPU backends (this
                 container is CPU-only) they execute in ``interpret=True``
                 mode: the kernel body runs in Python/XLA per grid step,
                 which validates correctness of the exact TPU program. On a
                 real TPU the same calls lower to Mosaic.
  'streaming'  — A-free Pallas kernels that regenerate affinity tiles on
                 the fly inside the power step (kernels/streaming.py).
  'reference'  — the pure-jnp oracles (kernels/ref.py), used by tests and
                 by benchmarks to compare fused-kernel vs unfused HLO.

The backend probe is evaluated ONCE at import (it cannot change within a
process) and can be pinned explicitly for CI / TPU runs with the
``REPRO_FORCE_INTERPRET`` env var: 1/true/interpret forces interpret mode,
0/false/compiled forces compiled Mosaic lowering.

Tile sizes default to the static autotuner in kernels/tuning.py; pass
``tm``/``tn`` to override.

Graceful degradation (DESIGN.md §12): every public wrapper guards its
kernel dispatch — a Pallas lowering/compile failure (or a fault injected
with ``forced_kernel_failure``) degrades that op to the 'reference' oracle
for the rest of the process and records the reason in
``kernel_fallbacks()``, which the pipeline surfaces as health notes.
"""
from __future__ import annotations

import contextlib
import os
from typing import Callable

import jax
import jax.numpy as jnp

from . import ref
from .affinity import affinity_and_degree as _affinity_pallas
from .block_sparse import block_liveness as _liveness_pallas
from .block_sparse import block_sparse_matmat as _bs_matmat_pallas
from .block_sparse import (
    block_sparse_streaming_degree as _bs_degree_streaming,
)
from .block_sparse import (
    block_sparse_streaming_matmat as _bs_streaming_pallas,
)
from .gram import gram as _gram_pallas
from .kmeans_assign import kmeans_assign as _assign_pallas
from .power_step import degree_normalized_matmat as _dnmm_pallas
from .power_step import degree_normalized_matvec as _dnmv_pallas
from .power_step import power_step as _power_pallas
from .row_topk import row_topk as _row_topk_pallas
from .streaming import affinity_degree_streaming as _degree_streaming
from .streaming import affinity_matmat as _streaming_pallas
from .tuning import choose_tiles

_INTERPRET_ENV = "REPRO_FORCE_INTERPRET"


def _probe_interpret() -> bool:
    """True when kernels must run in interpret mode (once, at import)."""
    val = os.environ.get(_INTERPRET_ENV, "").strip().lower()
    if val in ("1", "true", "interpret"):
        return True
    if val in ("0", "false", "compiled"):
        return False
    return jax.default_backend() != "tpu"


_INTERPRET: bool = _probe_interpret()


def _interpret() -> bool:
    return _INTERPRET


# ---------------------------------------------------------------------------
# (op, mode) registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[tuple[str, str], Callable] = {}


def register(op: str, mode: str):
    """Decorator: register ``fn`` as the ``mode`` implementation of ``op``."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(op, mode)] = fn
        return fn

    return deco


def dispatch(op: str, mode: str) -> Callable:
    """Resolve an implementation; raises with the available modes on miss."""
    try:
        return _REGISTRY[(op, mode)]
    except KeyError:
        raise ValueError(
            f"no {mode!r} implementation of {op!r}; available: "
            f"{modes_for(op) or '(none)'}"
        ) from None


def modes_for(op: str) -> tuple[str, ...]:
    return tuple(sorted(m for (o, m) in _REGISTRY if o == op))


def _resolve_mode(mode: str | None, force_reference: bool,
                  default: str = "pallas") -> str:
    if mode is not None:
        return mode
    return "reference" if force_reference else default


# ---------------------------------------------------------------------------
# Graceful degradation: per-op kernel → reference fallback (DESIGN.md §12)
# ---------------------------------------------------------------------------

_FALLBACKS: dict[str, str] = {}
_FORCED_FAILURES: dict[str, str] = {}


def kernel_fallbacks() -> dict[str, str]:
    """Snapshot of ops that have degraded to the reference oracle in this
    process: ``{op: reason}``. The pipeline diffs this around each entry
    call to attach ``kernel_fallback:<op>`` notes to the health report."""
    return dict(_FALLBACKS)


def reset_kernel_fallbacks() -> None:
    """Forget recorded fallbacks so ops dispatch to kernels again. Pair
    with ``jax.clear_caches()``: dispatch happens at trace time, so a
    cached jit program keeps whatever path it was traced with."""
    _FALLBACKS.clear()


@contextlib.contextmanager
def forced_kernel_failure(op: str, reason: str = "forced kernel failure"):
    """Fault injection: make the next kernel dispatch of ``op`` raise, so
    the guarded wrapper exercises its reference fallback. Pair with
    ``jax.clear_caches()`` before AND after — dispatch is a trace-time
    decision, so cached programs bypass both the fault and the recovery."""
    _FORCED_FAILURES[op] = reason
    try:
        yield
    finally:
        _FORCED_FAILURES.pop(op, None)


def _guarded(op: str, kernel_thunk: Callable, ref_thunk: Callable):
    """Run the fused kernel; if it raises (Pallas lowering/compile failure
    or an injected fault), degrade to the jnp reference oracle, record the
    reason once, and keep serving the oracle for the rest of the process.
    Same math, unfused HLO — a slow correct answer instead of a crash."""
    if op in _FALLBACKS:
        return ref_thunk()
    try:
        if op in _FORCED_FAILURES:
            raise RuntimeError(_FORCED_FAILURES[op])
        return kernel_thunk()
    except Exception as e:  # noqa: BLE001 — any lowering failure degrades
        _FALLBACKS[op] = f"{type(e).__name__}: {e}"
        return ref_thunk()


def _tiles(n: int, tm: int | None, tn: int | None, *, r: int = 1,
           m: int = 0, a_bytes: int = 4) -> tuple[int, int]:
    """Resolve (tm, tn): explicit overrides win, else the static autotuner
    keyed on the wide dimension ``n``. Rectangular stripe sweeps
    deliberately use the SAME tile choice as the square build (not one
    shrunk to the stripe height): distributed-vs-single-device trajectory
    parity rests on the two paths compiling the same tiled program, and
    in interpret mode even a row-only tile change perturbs XLA fusion and
    hence f32 rounding. The cost — padding a short (n/P) row block up to
    the square-build tile — is a TPU-tuning follow-up (see ROADMAP).
    Exception: the streaming ring's stages are (n/P, n/P) blocks, so their
    ``n`` IS the block size — ring tiling intentionally differs from the
    single-device streaming sweep (ulp-level parity; DESIGN.md §9)."""
    if tm is not None and tn is not None:
        return tm, tn
    atm, atn = choose_tiles(n, r=r, m=m, a_bytes=a_bytes)
    return tm or atm, tn or atn


def resolve_tiles(n: int, tm: int | None = None, tn: int | None = None, *,
                  r: int = 1, m: int = 0, a_bytes: int = 4) -> tuple[int, int]:
    """Public tile resolution with the wrappers' exact policy — operators
    building a block plan call this ONCE and pass the pinned (tm, tn) into
    every sweep that consumes the plan: the autotuner's choice depends on
    the call shape (r enters the VMEM fit), so per-call resolution could
    hand the probe's r=1 matmat a different grid than the power sweep's
    and misalign the plan's block coordinates."""
    return _tiles(n, tm, tn, r=r, m=m, a_bytes=a_bytes)


# -- registrations ----------------------------------------------------------

register("affinity_and_degree", "pallas")(_affinity_pallas)
register("affinity_and_degree", "reference")(ref.affinity_and_degree_ref)
register("degree_normalized_matvec", "pallas")(_dnmv_pallas)
register("degree_normalized_matvec", "reference")(ref.degree_normalized_matvec_ref)
register("degree_normalized_matmat", "pallas")(_dnmm_pallas)
register("degree_normalized_matmat", "reference")(ref.degree_normalized_matmat_ref)
register("streaming_matmat", "streaming")(_streaming_pallas)
register("streaming_matmat", "reference")(ref.affinity_matmat_ref)
register("streaming_degree", "streaming")(_degree_streaming)
register("streaming_degree", "reference")(ref.affinity_degree_streaming_ref)
register("power_step", "pallas")(_power_pallas)
register("power_step", "reference")(ref.power_step_ref)
register("gram", "pallas")(_gram_pallas)
register("gram", "reference")(ref.gram_ref)
register("kmeans_assign", "pallas")(_assign_pallas)
register("kmeans_assign", "reference")(ref.kmeans_assign_ref)
register("row_topk", "pallas")(_row_topk_pallas)
register("row_topk", "reference")(ref.row_topk_ref)
register("block_sparse_matmat", "pallas")(_bs_matmat_pallas)
register("block_sparse_matmat", "reference")(ref.block_sparse_matmat_ref)
register("block_sparse_streaming_matmat", "streaming")(_bs_streaming_pallas)
register("block_sparse_streaming_matmat", "reference")(
    ref.block_sparse_streaming_matmat_ref)
register("block_sparse_streaming_degree", "streaming")(_bs_degree_streaming)
register("block_sparse_streaming_degree", "reference")(
    ref.block_sparse_streaming_degree_ref)
register("block_liveness", "pallas")(_liveness_pallas)
register("block_liveness", "reference")(ref.block_liveness_ref)


def _spec_kind_sigma(spec, kind: str, sigma: float) -> tuple[str, float]:
    """Resolve (kind, sigma) with an AffinitySpec taking precedence over
    the legacy loose kwargs (duck-typed: any object with .kind/.sigma)."""
    if spec is None:
        return kind, sigma
    return spec.kind, float(spec.sigma)


# ---------------------------------------------------------------------------
# Public jit-friendly wrappers (stable API; modules call these, not the
# registry directly).
# ---------------------------------------------------------------------------


def affinity_and_degree(xn, xc=None, *, kind="cosine_shifted", sigma=1.0,
                        spec=None, scale_r=None, scale_c=None, thr=None,
                        tm=None, tn=None, out_dtype=jnp.float32,
                        row_offset=0, col_offset=0,
                        force_reference=False, mode=None):
    """Fused A + D build (paper kernels 1-2). See kernels/affinity.py.

    ``xc=None`` is the square self-affinity; with ``xc`` given the result
    is the (R, C) stripe at (row_offset, col_offset) of the global matrix
    — the sharded explicit path's per-device build (DESIGN.md §9).

    ``spec`` (an AffinitySpec) supplies kind/sigma; the pass-1 statistic
    arrays ``scale_r``/``scale_c`` (adaptive local scales) and ``thr``
    (per-row truncation thresholds) realize its policies in-tile
    (DESIGN.md §11).
    """
    kind, sigma = _spec_kind_sigma(spec, kind, sigma)
    mode = _resolve_mode(mode, force_reference)

    def _ref():
        a, deg = ref.affinity_and_degree_ref(
            xn, xc, kind=kind, sigma=sigma,
            row_offset=row_offset, col_offset=col_offset,
            scale_r=scale_r, scale_c=scale_c, thr=thr)
        return a.astype(out_dtype), deg   # honor O4 storage dtype here too

    if mode == "reference":
        return _ref()
    n = max(xn.shape[0], xn.shape[0] if xc is None else xc.shape[0])
    tm_, tn_ = _tiles(n, tm, tn, m=xn.shape[1],
                      a_bytes=jnp.dtype(out_dtype).itemsize)
    return _guarded("affinity_and_degree", lambda: dispatch(
        "affinity_and_degree", mode)(
        xn, xc, kind=kind, sigma=sigma, tm=tm_, tn=tn_, out_dtype=out_dtype,
        row_offset=row_offset, col_offset=col_offset,
        scale_r=scale_r, scale_c=scale_c, thr=thr,
        interpret=_interpret(),
    ), _ref)


def degree_normalized_matvec(a, v, d, *, tm=None, tn=None,
                             force_reference=False, mode=None):
    """u = (A v)/d — fused paper kernels 3+6 (W never materialized)."""
    mode = _resolve_mode(mode, force_reference)
    if mode == "reference":
        return ref.degree_normalized_matvec_ref(a, v, d)
    tm_, tn_ = _tiles(a.shape[0], tm, tn, a_bytes=a.dtype.itemsize)
    return _guarded("degree_normalized_matvec", lambda: dispatch(
        "degree_normalized_matvec", mode)(
        a, v, d, tm=tm_, tn=tn_, interpret=_interpret()
    ), lambda: ref.degree_normalized_matvec_ref(a, v, d))


def degree_normalized_matmat(a, v, d, *, tm=None, tn=None,
                             force_reference=False, mode=None):
    """U = (A V)/d for V (C, r) — ONE HBM sweep of A for all r vectors.

    ``a`` may be a rectangular (R, C) row stripe of the global matrix (the
    sharded explicit path, DESIGN.md §9); d is the stripe's (R,) degrees.
    """
    mode = _resolve_mode(mode, force_reference)
    if mode == "reference":
        return ref.degree_normalized_matmat_ref(a, v, d)
    tm_, tn_ = _tiles(max(a.shape), tm, tn, r=v.shape[1],
                      a_bytes=a.dtype.itemsize)
    return _guarded("degree_normalized_matmat", lambda: dispatch(
        "degree_normalized_matmat", mode)(
        a, v, d, tm=tm_, tn=tn_, interpret=_interpret()
    ), lambda: ref.degree_normalized_matmat_ref(a, v, d))


def streaming_matmat(x, v, d=None, xc=None, *, kind="cosine_shifted",
                     sigma=1.0, spec=None, scale_r=None, scale_c=None,
                     thr=None, thr_c=None, tm=None, tn=None,
                     row_offset=0, col_offset=0,
                     force_reference=False, mode=None):
    """U = (A V)/d with A regenerated on the fly — no (n, n) allocation.

    With ``xc`` given, computes the (R, r) partial product of the stripe
    at (row_offset, col_offset) against col features xc (C, m) and V
    (C, r) — one ring stage of the sharded streaming engine. ``d=None``
    skips the degree normalization so stripe partials can accumulate.
    ``spec``/``scale_r``/``scale_c``/``thr`` as in :func:`affinity_and_degree`;
    ``thr_c`` (C,) applies each COLUMN's own threshold instead — the
    Aᵀ-stripe product of the symmetrized reachability probe.
    """
    kind, sigma = _spec_kind_sigma(spec, kind, sigma)
    mode = _resolve_mode(mode, force_reference, default="streaming")

    def _ref():
        return ref.affinity_matmat_ref(x, v, d, xc, kind=kind, sigma=sigma,
                                       row_offset=row_offset,
                                       col_offset=col_offset,
                                       scale_r=scale_r, scale_c=scale_c,
                                       thr=thr, thr_c=thr_c)

    if mode == "reference":
        return _ref()
    n = max(x.shape[0], x.shape[0] if xc is None else xc.shape[0])
    tm_, tn_ = _tiles(n, tm, tn, r=v.shape[1], m=x.shape[1])
    return _guarded("streaming_matmat", lambda: dispatch(
        "streaming_matmat", mode)(
        x, v, d, xc, kind=kind, sigma=sigma, tm=tm_, tn=tn_,
        row_offset=row_offset, col_offset=col_offset,
        scale_r=scale_r, scale_c=scale_c, thr=thr, thr_c=thr_c,
        interpret=_interpret(),
    ), _ref)


def streaming_degree(x, xc=None, *, kind="cosine_shifted", sigma=1.0,
                     spec=None, scale_r=None, scale_c=None, thr=None,
                     tm=None, tn=None, row_offset=0, col_offset=0,
                     force_reference=False, mode=None):
    """Degree vector D = A 1 in one streamed sweep (RowSum without A).

    With ``xc`` given, returns the partial row sums of the stripe at
    (row_offset, col_offset) over that column block only.
    ``spec``/``scale_r``/``scale_c``/``thr`` as in :func:`affinity_and_degree`.
    """
    kind, sigma = _spec_kind_sigma(spec, kind, sigma)
    mode = _resolve_mode(mode, force_reference, default="streaming")

    def _ref():
        return ref.affinity_degree_streaming_ref(
            x, xc, kind=kind, sigma=sigma,
            row_offset=row_offset, col_offset=col_offset,
            scale_r=scale_r, scale_c=scale_c, thr=thr)

    if mode == "reference":
        return _ref()
    n = max(x.shape[0], x.shape[0] if xc is None else xc.shape[0])
    tm_, tn_ = _tiles(n, tm, tn, m=x.shape[1])
    return _guarded("streaming_degree", lambda: dispatch(
        "streaming_degree", mode)(
        x, xc, kind=kind, sigma=sigma, tm=tm_, tn=tn_,
        row_offset=row_offset, col_offset=col_offset,
        scale_r=scale_r, scale_c=scale_c, thr=thr,
        interpret=_interpret()
    ), _ref)


def row_topk(x, xc=None, *, k, stat="similarity", kind="cosine_shifted",
             sigma=1.0, spec=None, scale_r=None, scale_c=None,
             tm=None, tn=None, row_offset=0, col_offset=0,
             force_reference=False, mode=None):
    """(R, k) per-row descending top-k scores — pass 1 of the two-pass
    affinity-graph build (kernels/row_topk.py, DESIGN.md §11).

    ``stat='neg_sqdist'`` is the k-th-nearest-neighbor pass (adaptive local
    scales); ``stat='similarity'`` the truncation-threshold pass. Streamed:
    no (R, C) allocation in any mode but 'reference'.
    """
    kind, sigma = _spec_kind_sigma(spec, kind, sigma)
    mode = _resolve_mode(mode, force_reference)

    def _ref():
        return ref.row_topk_ref(x, xc, k=k, stat=stat, kind=kind, sigma=sigma,
                                scale_r=scale_r, scale_c=scale_c,
                                row_offset=row_offset, col_offset=col_offset)

    if mode == "reference":
        return _ref()
    n = max(x.shape[0], x.shape[0] if xc is None else xc.shape[0])
    tm_, tn_ = _tiles(n, tm, tn, m=x.shape[1])
    return _guarded("row_topk", lambda: dispatch("row_topk", mode)(
        x, xc, k=k, stat=stat, kind=kind, sigma=sigma, tm=tm_, tn=tn_,
        row_offset=row_offset, col_offset=col_offset,
        scale_r=scale_r, scale_c=scale_c,
        interpret=_interpret(),
    ), _ref)


def block_sparse_matmat(a, v, d, counts, col_idx, max_b, *, tm, tn,
                        force_reference=False, mode=None):
    """U = (A V)/d visiting only the plan's live blocks (DESIGN.md §13).

    Tiles are REQUIRED here (no autotuning): the plan's block coordinates
    are only meaningful on the grid they were computed for, so the caller
    pins (tm, tn) once via :func:`resolve_tiles` and reuses them for the
    plan and every sweep. Bitwise-equal to :func:`degree_normalized_matmat`
    at the same tiles.
    """
    mode = _resolve_mode(mode, force_reference)

    def _ref():
        return ref.block_sparse_matmat_ref(a, v, d, counts, col_idx,
                                           tm=tm, tn=tn)

    if mode == "reference":
        return _ref()
    return _guarded("block_sparse_matmat", lambda: dispatch(
        "block_sparse_matmat", mode)(
        a, v, d, counts, col_idx, max_b, tm=tm, tn=tn,
        interpret=_interpret(),
    ), _ref)


def block_sparse_streaming_matmat(x, v, d=None, xc=None, *, counts, col_idx,
                                  max_b, kind="cosine_shifted", sigma=1.0,
                                  spec=None, scale_r=None, scale_c=None,
                                  thr=None, tm, tn, row_offset=0,
                                  col_offset=0, force_reference=False,
                                  mode=None):
    """Streaming U = (A V)/d regenerating only live feature tiles — the
    A-free twin of :func:`block_sparse_matmat` (same pinned-tile contract;
    ``d=None`` leaves ring-stage partials unnormalized)."""
    kind, sigma = _spec_kind_sigma(spec, kind, sigma)
    mode = _resolve_mode(mode, force_reference, default="streaming")

    def _ref():
        return ref.block_sparse_streaming_matmat_ref(
            x, v, d, xc, counts=counts, col_idx=col_idx, tm=tm, tn=tn,
            kind=kind, sigma=sigma,
            row_offset=row_offset, col_offset=col_offset,
            scale_r=scale_r, scale_c=scale_c, thr=thr)

    if mode == "reference":
        return _ref()
    return _guarded("block_sparse_streaming_matmat", lambda: dispatch(
        "block_sparse_streaming_matmat", mode)(
        x, v, d, xc, counts=counts, col_idx=col_idx, max_b=max_b,
        kind=kind, sigma=sigma, tm=tm, tn=tn,
        row_offset=row_offset, col_offset=col_offset,
        scale_r=scale_r, scale_c=scale_c, thr=thr,
        interpret=_interpret(),
    ), _ref)


def block_sparse_streaming_degree(x, xc=None, *, counts, col_idx, max_b,
                                  kind="cosine_shifted", sigma=1.0, spec=None,
                                  scale_r=None, scale_c=None, thr=None,
                                  tm, tn, row_offset=0, col_offset=0,
                                  force_reference=False, mode=None):
    """Degree vector over live blocks only (same pinned-tile contract)."""
    kind, sigma = _spec_kind_sigma(spec, kind, sigma)
    mode = _resolve_mode(mode, force_reference, default="streaming")

    def _ref():
        return ref.block_sparse_streaming_degree_ref(
            x, xc, counts=counts, col_idx=col_idx, tm=tm, tn=tn,
            kind=kind, sigma=sigma,
            row_offset=row_offset, col_offset=col_offset,
            scale_r=scale_r, scale_c=scale_c, thr=thr)

    if mode == "reference":
        return _ref()
    return _guarded("block_sparse_streaming_degree", lambda: dispatch(
        "block_sparse_streaming_degree", mode)(
        x, xc, counts=counts, col_idx=col_idx, max_b=max_b,
        kind=kind, sigma=sigma, tm=tm, tn=tn,
        row_offset=row_offset, col_offset=col_offset,
        scale_r=scale_r, scale_c=scale_c, thr=thr,
        interpret=_interpret(),
    ), _ref)


def block_liveness(x, xc=None, *, kind="cosine_shifted", sigma=1.0, spec=None,
                   scale_r=None, scale_c=None, thr=None, tm, tn,
                   row_offset=0, col_offset=0, force_reference=False,
                   mode=None):
    """(nI, nJ) int32 live-block map of the masked stripe, A-free — the
    plan source for streaming engines (explicit engines read liveness off
    the stored matrix with core.affinity.dense_block_live instead)."""
    kind, sigma = _spec_kind_sigma(spec, kind, sigma)
    mode = _resolve_mode(mode, force_reference)

    def _ref():
        return ref.block_liveness_ref(
            x, xc, tm=tm, tn=tn, kind=kind, sigma=sigma,
            row_offset=row_offset, col_offset=col_offset,
            scale_r=scale_r, scale_c=scale_c, thr=thr)

    if mode == "reference":
        return _ref()
    return _guarded("block_liveness", lambda: dispatch(
        "block_liveness", mode)(
        x, xc, kind=kind, sigma=sigma, tm=tm, tn=tn,
        row_offset=row_offset, col_offset=col_offset,
        scale_r=scale_r, scale_c=scale_c, thr=thr,
        interpret=_interpret(),
    ), _ref)


def power_step(a, v, d, *, tm=None, tn=None, force_reference=False,
               mode=None):
    """v' = W v / ||W v||_1 — one full paper iteration (kernels 6+4+5)."""
    mode = _resolve_mode(mode, force_reference)
    if mode == "reference":
        return ref.power_step_ref(a, v, d)
    r = 1 if v.ndim == 1 else v.shape[1]
    tm_, tn_ = _tiles(a.shape[0], tm, tn, r=r, a_bytes=a.dtype.itemsize)
    return _guarded("power_step", lambda: dispatch("power_step", mode)(
        a, v, d, tm=tm_, tn=tn_, interpret=_interpret()
    ), lambda: ref.power_step_ref(a, v, d))


def gram(v, *, tm=512, force_reference=False, mode=None):
    """G = VᵀV for the tall-skinny (n, r) engine state — the reduction that
    prices the block re-orthonormalization (DESIGN.md §10). One HBM sweep
    of V, f32 accumulation. Sharded callers compute the LOCAL chunk's Gram
    here and finish with the operator's ``sum`` primitive."""
    mode = _resolve_mode(mode, force_reference)
    if mode == "reference":
        return ref.gram_ref(v)
    return _guarded("gram", lambda: dispatch("gram", mode)(
        v, tm=tm, interpret=_interpret()), lambda: ref.gram_ref(v))


def kmeans_assign(x, cents, *, tm=512, force_reference=False, mode=None):
    """k-means assignment (labels, sq-dists)."""
    mode = _resolve_mode(mode, force_reference)
    if mode == "reference":
        return ref.kmeans_assign_ref(x, cents)
    return _guarded("kmeans_assign", lambda: dispatch("kmeans_assign", mode)(
        x, cents, tm=tm, interpret=_interpret()
    ), lambda: ref.kmeans_assign_ref(x, cents))


def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512,
                    force_reference=False):
    """Causal flash attention, GQA-aware (LM-substrate hot-spot kernel)."""
    from .flash_attention import flash_attention as _flash_pallas
    if force_reference:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_pallas(q, k, v, causal=causal, block_q=block_q,
                        block_k=block_k, interpret=_interpret())
