"""Public jit'd wrappers over the Pallas kernels.

On non-TPU backends (this container is CPU-only) the kernels execute in
``interpret=True`` mode — the kernel body runs in Python/XLA per grid step,
which validates correctness of the exact TPU program. On a real TPU the same
calls lower to Mosaic. ``force_reference`` routes to the pure-jnp oracle
(used by benchmarks to compare fused-kernel vs unfused-reference HLO).
"""
from __future__ import annotations

import jax

from . import ref
from .affinity import affinity_and_degree as _affinity_pallas
from .kmeans_assign import kmeans_assign as _assign_pallas
from .power_step import degree_normalized_matvec as _dnmv_pallas
from .power_step import power_step as _power_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def affinity_and_degree(xn, *, kind="cosine_shifted", sigma=1.0,
                        tm=256, tn=256, force_reference=False):
    """Fused A + D build (paper kernels 1-2). See kernels/affinity.py."""
    if force_reference:
        return ref.affinity_and_degree_ref(xn, kind=kind, sigma=sigma)
    return _affinity_pallas(
        xn, kind=kind, sigma=sigma, tm=tm, tn=tn, interpret=_interpret()
    )


def degree_normalized_matvec(a, v, d, *, tm=256, tn=256, force_reference=False):
    """u = (A v)/d — fused paper kernels 3+6 (W never materialized)."""
    if force_reference:
        return ref.degree_normalized_matvec_ref(a, v, d)
    return _dnmv_pallas(a, v, d, tm=tm, tn=tn, interpret=_interpret())


def power_step(a, v, d, *, tm=256, tn=256, force_reference=False):
    """v' = W v / ||W v||_1 — one full paper iteration (kernels 6+4+5)."""
    if force_reference:
        return ref.power_step_ref(a, v, d)
    return _power_pallas(a, v, d, tm=tm, tn=tn, interpret=_interpret())


def kmeans_assign(x, cents, *, tm=512, force_reference=False):
    """k-means assignment (labels, sq-dists)."""
    if force_reference:
        return ref.kmeans_assign_ref(x, cents)
    return _assign_pallas(x, cents, tm=tm, interpret=_interpret())


def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512,
                    force_reference=False):
    """Causal flash attention, GQA-aware (LM-substrate hot-spot kernel)."""
    from .flash_attention import flash_attention as _flash_pallas
    if force_reference:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_pallas(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k, interpret=_interpret())
