"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _affinity_scores_ref(
    x: jax.Array,
    c: jax.Array,
    *,
    kind: str,
    sigma: float,
    scale_r: jax.Array | None,
    scale_c: jax.Array | None,
) -> jax.Array:
    """Dense (R, C) similarity scores before any masking — the one place
    the reference similarity transform (fixed or adaptive bandwidth) lives."""
    if kind in ("cosine", "cosine_shifted"):
        a = x @ c.T
        if kind == "cosine_shifted":
            a = 0.5 * (1.0 + a)
        return a
    if kind == "rbf":
        sqr = jnp.sum(x * x, axis=1)
        sqc = jnp.sum(c * c, axis=1)
        d2 = jnp.maximum(sqr[:, None] + sqc[None, :] - 2.0 * (x @ c.T), 0.0)
        if scale_r is not None:
            return jnp.exp(-d2 / (scale_r.astype(jnp.float32)[:, None]
                                  * scale_c.astype(jnp.float32)[None, :]))
        return jnp.exp(-d2 / (2.0 * sigma * sigma))
    raise ValueError(kind)


def affinity_and_degree_ref(
    xn: jax.Array,
    xc: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.affinity.affinity_and_degree (stripe-general).

    ``scale_r``/``scale_c`` are the (R,)/(C,) adaptive local scales (rbf
    only; replaces the 2 sigma^2 denominator with scale_i * scale_j);
    ``thr`` is the (R,) per-row truncation threshold — entries strictly
    below it are zeroed (DESIGN.md §11).
    """
    x = xn.astype(jnp.float32)
    c = x if xc is None else xc.astype(jnp.float32)
    a = _affinity_scores_ref(x, c, kind=kind, sigma=sigma,
                             scale_r=scale_r, scale_c=scale_c)
    grows = row_offset + jnp.arange(a.shape[0])[:, None]
    gcols = col_offset + jnp.arange(a.shape[1])[None, :]
    valid = grows != gcols
    if thr is not None:
        valid = valid & (a >= thr.astype(jnp.float32)[:, None])
    a = jnp.where(valid, a, 0.0)
    return a, jnp.sum(a, axis=1)


def row_topk_ref(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    k: int,
    stat: str = "similarity",
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
) -> jax.Array:
    """Oracle for kernels.row_topk.row_topk: per-row descending top-k of

      stat='similarity'  the affinity value (kind/sigma/scales applied)
      stat='neg_sqdist'  -||x_i - c_j||^2  (so [:, k-1] is the k-th
                         nearest-neighbor statistic)

    over the VALID entries of the stripe (global diagonal excluded). Rows
    with fewer than k valid entries pad with -inf.
    """
    x = x.astype(jnp.float32)
    c = x if xc is None else xc.astype(jnp.float32)
    if stat == "similarity":
        s = _affinity_scores_ref(x, c, kind=kind, sigma=sigma,
                                 scale_r=scale_r, scale_c=scale_c)
    elif stat == "neg_sqdist":
        sqr = jnp.sum(x * x, axis=1)
        sqc = jnp.sum(c * c, axis=1)
        s = -jnp.maximum(sqr[:, None] + sqc[None, :] - 2.0 * (x @ c.T), 0.0)
    else:
        raise ValueError(f"unknown stat {stat!r}")
    grows = row_offset + jnp.arange(s.shape[0])[:, None]
    gcols = col_offset + jnp.arange(s.shape[1])[None, :]
    s = jnp.where(grows != gcols, s, -jnp.inf)
    return jax.lax.top_k(s, k)[0]


def _floored_degree_divide(u: jax.Array, d: jax.Array) -> jax.Array:
    """u / d with the floored reciprocal the Pallas kernels use — already
    zero-degree safe (d = 0 implies the whole nonnegative A row, hence u,
    is an exact 0; NaN degrees propagate to the loop's non-finite latch).
    The divide form is pinned: masked-where variants are value-identical
    on healthy rows but perturb interpret-mode XLA fusion and break
    local/sharded trajectory parity (DESIGN.md §12)."""
    return u / jnp.maximum(d.astype(jnp.float32), 1e-30)


def degree_normalized_matvec_ref(
    a: jax.Array, v: jax.Array, d: jax.Array
) -> jax.Array:
    """Oracle for kernels.power_step.degree_normalized_matvec."""
    u = a.astype(jnp.float32) @ v.astype(jnp.float32)
    return _floored_degree_divide(u, d)


def degree_normalized_matmat_ref(
    a: jax.Array, v: jax.Array, d: jax.Array
) -> jax.Array:
    """Oracle for kernels.power_step.degree_normalized_matmat (v is (n, r))."""
    u = a.astype(jnp.float32) @ v.astype(jnp.float32)
    return _floored_degree_divide(u, d[:, None])


def affinity_matmat_ref(
    x: jax.Array,
    v: jax.Array,
    d: jax.Array | None = None,
    xc: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
    thr_c: jax.Array | None = None,
) -> jax.Array:
    """Oracle for kernels.streaming.affinity_matmat: (A @ V) / d, dense A.
    ``thr_c`` masks each COLUMN below its own threshold (the Aᵀ-stripe
    product of the symmetrized reachability probe)."""
    a, _ = affinity_and_degree_ref(x, xc, kind=kind, sigma=sigma,
                                   row_offset=row_offset,
                                   col_offset=col_offset,
                                   scale_r=scale_r, scale_c=scale_c, thr=thr)
    if thr_c is not None:
        a = jnp.where(a >= thr_c.astype(jnp.float32)[None, :], a, 0.0)
    u = a @ v.astype(jnp.float32)
    if d is None:
        return u
    return _floored_degree_divide(u, d[:, None])


def affinity_degree_streaming_ref(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> jax.Array:
    """Oracle for kernels.streaming.affinity_degree_streaming."""
    _, deg = affinity_and_degree_ref(x, xc, kind=kind, sigma=sigma,
                                     row_offset=row_offset,
                                     col_offset=col_offset,
                                     scale_r=scale_r, scale_c=scale_c,
                                     thr=thr)
    return deg


def gram_ref(v: jax.Array) -> jax.Array:
    """Oracle for kernels.gram.gram: G = VᵀV in f32."""
    v32 = v.astype(jnp.float32)
    return v32.T @ v32


def power_step_ref(a: jax.Array, v: jax.Array, d: jax.Array) -> jax.Array:
    """Oracle for kernels.power_step.power_step."""
    u = degree_normalized_matvec_ref(a, v, d)
    return u / jnp.maximum(jnp.sum(jnp.abs(u)), 1e-30)


def flash_attention_ref(q, k, v, *, causal=True):
    """Oracle for kernels.flash_attention: q (bh, s, d), k/v (bkv, s, d)."""
    bh, s, d = q.shape
    rep = bh // k.shape[0]
    kk = jnp.repeat(k, rep, axis=0).astype(jnp.float32)
    vv = jnp.repeat(v, rep, axis=0).astype(jnp.float32)
    logits = jnp.einsum("hsd,htd->hst", q.astype(jnp.float32), kk)
    logits = logits / jnp.sqrt(jnp.asarray(d, jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hst,htd->hsd", probs, vv).astype(q.dtype)


def kmeans_assign_ref(
    x: jax.Array, cents: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.kmeans_assign.kmeans_assign."""
    x = x.astype(jnp.float32)
    c = cents.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    cc = jnp.sum(c * c, axis=1)[None, :]
    d2 = xx + cc - 2.0 * (x @ c.T)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def _plan_live_ref(counts: jax.Array, col_idx: jax.Array) -> jax.Array:
    """(nI, nJ) boolean live map from a block plan (scatter with .max so
    the padded dead-id tail never clobbers a live block)."""
    n_i, n_j = col_idx.shape
    slot_live = jnp.arange(n_j)[None, :] < counts[:, None]
    live = jnp.zeros((n_i, n_j), bool)
    return live.at[jnp.arange(n_i)[:, None], col_idx].max(slot_live)


def _apply_plan_ref(a: jax.Array, counts, col_idx, tm: int, tn: int):
    """Zero every block of ``a`` the plan marks dead (tile grid padded to
    (tm, tn) multiples like the kernels pad)."""
    n_rows, n_cols = a.shape
    rp = -(-n_rows // tm) * tm
    cp = -(-n_cols // tn) * tn
    ap = jnp.pad(a, ((0, rp - n_rows), (0, cp - n_cols)))
    live = _plan_live_ref(counts, col_idx)
    mask = jnp.repeat(jnp.repeat(live, tm, axis=0), tn, axis=1)
    return jnp.where(mask, ap, 0.0)[:n_rows, :n_cols]


def block_sparse_matmat_ref(
    a: jax.Array, v: jax.Array, d: jax.Array,
    counts: jax.Array, col_idx: jax.Array, *, tm: int, tn: int
) -> jax.Array:
    """Oracle for kernels.block_sparse.block_sparse_matmat: the plan's dead
    blocks contribute nothing, everything else is the dense oracle."""
    return degree_normalized_matmat_ref(
        _apply_plan_ref(a.astype(jnp.float32), counts, col_idx, tm, tn), v, d)


def block_sparse_streaming_matmat_ref(
    x: jax.Array,
    v: jax.Array,
    d: jax.Array | None = None,
    xc: jax.Array | None = None,
    *,
    counts: jax.Array,
    col_idx: jax.Array,
    tm: int,
    tn: int,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> jax.Array:
    """Oracle for kernels.block_sparse.block_sparse_streaming_matmat."""
    a, _ = affinity_and_degree_ref(x, xc, kind=kind, sigma=sigma,
                                   row_offset=row_offset,
                                   col_offset=col_offset,
                                   scale_r=scale_r, scale_c=scale_c, thr=thr)
    u = _apply_plan_ref(a, counts, col_idx, tm, tn) @ v.astype(jnp.float32)
    if d is None:
        return u
    return _floored_degree_divide(u, d[:, None])


def block_sparse_streaming_degree_ref(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    counts: jax.Array,
    col_idx: jax.Array,
    tm: int,
    tn: int,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> jax.Array:
    """Oracle for kernels.block_sparse.block_sparse_streaming_degree."""
    a, _ = affinity_and_degree_ref(x, xc, kind=kind, sigma=sigma,
                                   row_offset=row_offset,
                                   col_offset=col_offset,
                                   scale_r=scale_r, scale_c=scale_c, thr=thr)
    return jnp.sum(_apply_plan_ref(a, counts, col_idx, tm, tn), axis=1)


def block_liveness_ref(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    tm: int,
    tn: int,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> jax.Array:
    """Oracle for kernels.block_sparse.block_liveness: per-(tm, tn)-tile
    any-nonzero of the masked stripe, padding blocks dead."""
    a, _ = affinity_and_degree_ref(x, xc, kind=kind, sigma=sigma,
                                   row_offset=row_offset,
                                   col_offset=col_offset,
                                   scale_r=scale_r, scale_c=scale_c, thr=thr)
    n_rows, n_cols = a.shape
    rp = -(-n_rows // tm) * tm
    cp = -(-n_cols // tn) * tn
    ap = jnp.pad(a, ((0, rp - n_rows), (0, cp - n_cols)))
    tiles = ap.reshape(rp // tm, tm, cp // tn, tn)
    return jnp.any(tiles != 0, axis=(1, 3)).astype(jnp.int32)
