"""Tile-size selection for the GPIC Pallas kernels (DESIGN.md §6).

The kernels are tiled over a (n/TM, n/TN) grid; the tile size trades
MXU utilization (bigger is better) against VMEM footprint and padding
waste (n is rounded up to lcm(TM, TN)). ``choose_tiles`` is a static,
shape-only heuristic — it sees only python ints, so it is safe to call
from inside a ``jax.jit`` region on traced arrays' ``.shape``.
"""
from __future__ import annotations

import math

#: candidate square tile edges, largest first (multiples of the 128-lane
#: MXU/VPU width; 8-sublane aligned for f32, 16 for bf16).
TILE_CANDIDATES = (512, 256, 128)

#: per-core VMEM budget the working set must fit in, with headroom for
#: Mosaic's double buffering (hence the factor 2 in the fit check).
VMEM_BUDGET_BYTES = 16 * 2**20


def round_up_to_lcm(n: int, tm: int, tn: int) -> int:
    """Smallest n' >= n divisible by both tm and tn (the kernel pad size)."""
    blk = math.lcm(tm, tn)
    return ((n + blk - 1) // blk) * blk


def tile_working_set_bytes(t: int, *, r: int = 1, m: int = 0,
                           a_bytes: int = 4) -> int:
    """HBM->VMEM bytes resident per grid step for a t x t tile.

    Counts the A tile (or, for the streaming kernel with feature width
    ``m`` > 0, the two feature slabs that regenerate it), the (t, r)
    V/U blocks in f32, and the (t, 1) degree block.
    """
    a_tile = t * t * a_bytes
    slabs = 2 * t * m * 4
    vecs = 2 * t * max(r, 1) * 4 + t * 4
    return a_tile + slabs + vecs


def choose_tiles(
    n: int,
    *,
    r: int = 1,
    m: int = 0,
    a_bytes: int = 4,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> tuple[int, int]:
    """Pick (tm, tn) for an n x n sweep with r power vectors.

    Policy (largest candidate wins):
      1. fit: 2x the per-step working set must fit in ``vmem_budget``
         (the 2x models Mosaic's input double buffering);
      2. waste: the lcm padding must not add more than max(n/4, 128)
         phantom rows — small problems get small tiles instead of
         mostly-padding grids.
    Falls back to the smallest candidate when nothing satisfies both.
    """
    for t in TILE_CANDIDATES:
        if 2 * tile_working_set_bytes(t, r=r, m=m, a_bytes=a_bytes) > vmem_budget:
            continue
        if round_up_to_lcm(n, t, t) - n > max(n // 4, 128):
            continue
        return t, t
    t = TILE_CANDIDATES[-1]
    return t, t
