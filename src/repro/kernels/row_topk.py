"""Pallas TPU kernel: streamed per-row top-k statistics (pass 1 of the
two-pass affinity-graph build, DESIGN.md §11).

The adaptive-bandwidth and kNN-truncation policies of
:class:`~repro.core.affinity.AffinitySpec` both reduce to ONE per-row order
statistic of the (n, n) score matrix:

  stat='neg_sqdist'    top-k of -||x_i - x_j||²  →  [:, k-1] is the k-th
                       nearest-neighbor distance (the self-tuning local
                       scale σᵢ, after sqrt(-·))
  stat='similarity'    top-k of the affinity value itself (kind / sigma /
                       adaptive scales applied)  →  [:, k-1] is the row's
                       truncation threshold τᵢ

Like every GPIC kernel this computes a general *stripe* (row slab × col
slab with global SMEM offsets masking the diagonal), and it is STREAMED:
each (i, j) grid step regenerates the (TM, TN) score tile on the MXU —
reusing the exact tile transform of the affinity kernels — and folds it
into a running (TM, K) top-k buffer in the output ref, accumulated across
the col-grid dimension. No (n, n) array ever exists, so pass 1 costs the
A-free paths nothing in residency.

The in-tile top-k is K rounds of extract-the-row-max over the
(TM, K + TN) merge candidates: max / compare / select ops only (VPU
friendly — no general sort), with an index tie-break so duplicated scores
are consumed one at a time. Rows with fewer than K valid entries pad with
-inf (callers bound k < n, so the k-th statistic itself is always finite).

Cost: O(K) VPU passes over each tile on top of the O(n² m / TILE) MXU
work — one extra "sweep" per clustering, amortized over every power
iteration that then runs on a k-sparse graph.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .affinity import (
    affinity_tile_transform,
    policy_specs_and_operands,
    unpack_policy_refs,
)

STATS = ("similarity", "neg_sqdist")

_NEG_INF = float("-inf")


def row_topk_merge(buf: jax.Array, cand: jax.Array, k: int) -> jax.Array:
    """Descending top-k over the columns of [buf | cand] — K rounds of
    masked row-max extraction (max/where/iota only, so the same code runs
    on the VPU inside the kernel and as plain jnp in the ring's cross-stage
    merge). Ties are consumed once each via a first-column tie-break."""
    s = jnp.concatenate([buf, cand], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    out = []
    for _ in range(k):
        m = jnp.max(s, axis=1, keepdims=True)
        out.append(m)
        first = jnp.min(jnp.where(s == m, cols, s.shape[1]),
                        axis=1, keepdims=True)
        s = jnp.where(cols == first, _NEG_INF, s)
    return jnp.concatenate(out, axis=1)


def _row_topk_kernel(
    off_ref,                           # (1, 2) SMEM: global row/col offsets
    *refs,
    stat: str, kind: str, n_rows: int, n_cols: int, tm: int, tn: int,
    k: int, inv_two_sigma_sq: float, adaptive: bool,
):
    refs = list(refs)
    o_ref = refs[-1]                   # (TM, K) running top-k buffer
    xr_ref, xc_ref, sqr_ref, sqc_ref = refs[:4]
    sclr_ref, sclc_ref, _thr, _thr_c = unpack_policy_refs(
        refs[4:-1], adaptive, truncate=False)

    i = pl.program_id(0)
    j = pl.program_id(1)

    xr = xr_ref[...]
    xc = xc_ref[...]
    dot = jax.lax.dot_general(
        xr, xc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    if stat == "similarity":
        s = affinity_tile_transform(
            dot, sqr_ref[...] if kind == "rbf" else None,
            sqc_ref[...] if kind == "rbf" else None,
            kind=kind, inv_two_sigma_sq=inv_two_sigma_sq,
            sclr=sclr_ref[...] if adaptive else None,
            sclc=sclc_ref[...] if adaptive else None,
        )
    elif stat == "neg_sqdist":
        d2 = sqr_ref[...] + sqc_ref[...].T - 2.0 * dot
        s = -jnp.maximum(d2, 0.0)
    else:
        raise ValueError(stat)

    lrows = i * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    lcols = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    grows = off_ref[0, 0] + lrows
    gcols = off_ref[0, 1] + lcols
    valid = (grows != gcols) & (lrows < n_rows) & (lcols < n_cols)
    s = jnp.where(valid, s, _NEG_INF)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = row_topk_merge(
            jnp.full((tm, k), _NEG_INF, jnp.float32), s, k)

    @pl.when(j != 0)
    def _merge():
        o_ref[...] = row_topk_merge(o_ref[...], s, k)


@functools.partial(
    jax.jit,
    static_argnames=("stat", "kind", "sigma", "k", "tm", "tn", "interpret"),
)
def row_topk(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    k: int,
    stat: str = "similarity",
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
) -> jax.Array:
    """(R, k) descending per-row top-k scores of the stripe of ``x`` vs
    ``xc`` (None = the square self-stripe), diagonal excluded.

    ``stat='similarity'`` scores with the affinity transform (pass
    ``scale_r``/``scale_c`` for adaptive rbf); ``stat='neg_sqdist'`` scores
    with the negated squared distance (the k-th nearest-neighbor pass).
    Rows with fewer than k valid entries pad with -inf — ring callers
    merge per-stage results with :func:`row_topk_merge`.
    """
    if stat not in STATS:
        raise ValueError(f"unknown stat {stat!r} (expected one of {STATS})")
    if xc is None:
        xc = x
    adaptive = scale_r is not None
    if adaptive and (kind != "rbf" or scale_c is None):
        raise ValueError("adaptive scaling needs kind='rbf' and both "
                         "scale_r and scale_c")
    n_rows, m = x.shape
    n_cols = xc.shape[0]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    xr32 = jnp.pad(x.astype(jnp.float32), ((0, rp - n_rows), (0, 0)))
    xc32 = jnp.pad(xc.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    sqr = jnp.sum(xr32 * xr32, axis=1, keepdims=True)
    sqc = jnp.sum(xc32 * xc32, axis=1, keepdims=True)
    off = jnp.array([row_offset, col_offset], jnp.int32).reshape(1, 2)

    grid = (rp // tm, cp // tn)
    kernel = functools.partial(
        _row_topk_kernel,
        stat=stat, kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
        k=k, inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        adaptive=adaptive,
    )
    in_specs = [
        pl.BlockSpec((1, 2), lambda i, j: (0, 0),
                     memory_space=pltpu.SMEM),        # global offsets
        pl.BlockSpec((tm, m), lambda i, j: (i, 0)),   # row slab
        pl.BlockSpec((tn, m), lambda i, j: (j, 0)),   # col slab
        pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # row sq-norms
        pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),   # col sq-norms
    ]
    operands = [off, xr32, xc32, sqr, sqc]
    pol_specs, pol_ops = policy_specs_and_operands(
        scale_r, scale_c, None, tm=tm, tn=tn, rp=rp, cp=cp,
        n_rows=n_rows, n_cols=n_cols)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs + pol_specs,
        out_specs=pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, k), jnp.float32),
        interpret=interpret,
    )(*operands, *pol_ops)
    return out[:n_rows]


def topk_thresholds_from_scores(
    scores: jax.Array,
    *,
    k: int,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
) -> jax.Array:
    """(R,) per-row k-th-largest similarity from an UNMASKED score stripe —
    the fused one-pass build's threshold epilogue (DESIGN.md §13).

    ``scores`` is the stripe the build kernel writes with ``thr=None``: the
    true similarity values everywhere except the global diagonal, which the
    kernel masks to 0. The diagonal is re-excluded here BY INDEX (never by
    value — plain-cosine scores can be negative, so a written 0 could
    outrank real entries) and the k-th order statistic taken with
    ``jnp.partition`` (an O(n) selection — an order of magnitude faster
    than ``lax.top_k``'s sorted-prefix on CPU, and the threshold only
    needs the VALUE, not the sorted prefix). Selection is exact, so the
    statistic equals the one the streamed ``row_topk`` kernel keeps: both
    paths score tiles through the shared ``affinity_tile_transform``, so
    the thresholds are bitwise-equal to the two-pass build's.
    """
    grows = row_offset + jnp.arange(scores.shape[0])[:, None]
    gcols = col_offset + jnp.arange(scores.shape[1])[None, :]
    s = jnp.where(grows == gcols, _NEG_INF, scores.astype(jnp.float32))
    return -jnp.partition(-s, k - 1, axis=1)[:, k - 1]
