"""Pallas TPU kernel: tall-skinny Gram matrix G = VᵀV (DESIGN.md §10).

The block re-orthonormalization of the orthogonal embedding mode needs
(n, r)ᵀ(n, r) products every ``qr_every`` sweeps — an O(n r²) reduction
whose input is the tall-skinny engine state. The kernel sweeps V once in
(TM, r) row tiles, runs the (r, TM) × (TM, r) outer contraction on the MXU
in f32, and accumulates the (r, r) result in VMEM across the row grid —
one HBM read of V, no (n, r) temporary, f32 accumulation regardless of the
state dtype.

Grid: (n/TM,). r pads to the 8-sublane boundary with zero columns (zeros
contribute zero Gram entries, so no masking epilogue is needed); rows pad
to a TM multiple the same way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(v_ref, g_ref):
    i = pl.program_id(0)
    v = v_ref[...].astype(jnp.float32)                   # (TM, rp)
    partial = jax.lax.dot_general(
        v, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                    # (rp, rp)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = partial

    @pl.when(i != 0)
    def _acc():
        g_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def gram(v: jax.Array, *, tm: int = 512, interpret: bool = False) -> jax.Array:
    """G = VᵀV for tall-skinny V (n, r); returns (r, r) f32."""
    n, r = v.shape
    rp = max(8, pl.cdiv(r, 8) * 8)
    n_pad = pl.cdiv(n, tm) * tm
    # pad in the NATIVE dtype — the kernel casts each tile on load, so a
    # bf16 state is read from HBM at bf16 width (a host-side f32 cast
    # would materialize an (n, r) temporary and double the read traffic)
    vp = jnp.pad(v, ((0, n_pad - n), (0, rp - r)))

    g = pl.pallas_call(
        _gram_kernel,
        grid=(n_pad // tm,),
        in_specs=[pl.BlockSpec((tm, rp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rp, rp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, rp), jnp.float32),
        interpret=interpret,
    )(vp)
    return g[:r, :r]
