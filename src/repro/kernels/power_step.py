"""Pallas TPU kernel: fused multi-vector power-iteration step.

TPU adaptation of the paper's ``Multiply`` + ``Reduction`` + ``Norm`` CUDA
kernels (DESIGN.md §2), generalized to r power vectors at once. Computes in
ONE sweep of A:

    U = (A @ V) / d          for V of shape (n, r) — the degree-normalized
                             mat-mat. W V = (D^-1 A) V = D^-1 (A V), so W is
                             never materialized: the paper's NormMatrix kernel
                             and its O(n^2) extra read+write disappear — O1b.
                             The skinny (TM, TN) x (TN, r) product runs on the
                             MXU and amortizes the single HBM read of each A
                             tile across all r vectors (DESIGN.md §4): r times
                             the flops for the same O(n^2) memory traffic.
    partial L1 mass of U     (per row-tile per column, combined on the VPU)

The final per-column division V_{t+1} = U / ||U||_1 is an O(n r) epilogue
outside the kernel (the tiny combine the paper does with its tree-Reduction
kernel; on TPU this is a trivial jnp.sum — the CUDA interleaved-addressing
pattern has no TPU analogue, see DESIGN.md §8).

A may be rectangular (R, C): the sharded explicit path (DESIGN.md §9) runs
this kernel on its local (n/P, n) row stripe against the replicated V — the
same program the single-device square sweep compiles to, just a shorter
row grid.

A may be stored in bf16 (O4): tiles are upcast to f32 on load so the MXU
accumulates in f32 while HBM traffic halves (DESIGN.md §6).

Grid: (R/TM, C/TN), accumulating the product across the col-grid dimension j
(TPU grid order is sequential, minor-to-major, so revisiting the same output
block is the idiomatic accumulation pattern). Rows pad to a TM multiple and
columns to a TN multiple independently, so any tile pair divides evenly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _power_step_kernel(a_ref, v_ref, d_ref, u_ref, *, nj: int):
    j = pl.program_id(1)

    a = a_ref[...].astype(jnp.float32)   # (TM, TN) tile of A (f32 or bf16)
    v = v_ref[...]                       # (TN, r) slice of V
    partial = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                    # (TM, r)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        u_ref[...] += partial

    # last col-step: normalize the accumulated row block by the degree.
    # The floored divide is already zero-degree safe: d = 0 means the whole
    # A row is zero (nonnegative entries), so the accumulated u row is an
    # exact 0 and 0/1e-30 stays exactly 0; a NaN degree propagates NaN into
    # the iterate, which the loop's non-finite latch catches (DESIGN.md
    # §12). The divide form itself is pinned — a masked-where variant is
    # value-identical on healthy rows but perturbs interpret-mode XLA
    # fusion enough to break local/sharded trajectory parity (the
    # kernels/ops.py::_tiles discipline). Padding rows carry d = 1.0.
    @pl.when(j == nj - 1)
    def _norm():
        d = d_ref[...]                   # (TM, 1)
        u_ref[...] = u_ref[...] / jnp.maximum(d, 1e-30)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def degree_normalized_matmat(
    a: jax.Array,
    v: jax.Array,
    d: jax.Array,
    *,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """U = (A @ V) / d[:, None], one fused HBM sweep of A for all r columns.

    Shapes: a (R, C) [f32 or bf16 storage; R == C on the single-device
    square sweep, R == n/P on a sharded row stripe], v (C, r), d (R,);
    returns (R, r) f32. The single-vector ``degree_normalized_matvec`` is
    the r=1 case.
    """
    n_rows, n_cols = a.shape
    r = v.shape[1]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    if rp != n_rows or cp != n_cols:
        a = jnp.pad(a, ((0, rp - n_rows), (0, cp - n_cols)))
    if cp != n_cols:
        v = jnp.pad(v, ((0, cp - n_cols), (0, 0)))
    if rp != n_rows:
        d = jnp.pad(d, (0, rp - n_rows), constant_values=1.0)

    grid = (rp // tm, cp // tn)
    u = pl.pallas_call(
        functools.partial(_power_step_kernel, nj=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, r), jnp.float32),
        interpret=interpret,
    )(a, v.astype(jnp.float32), d.astype(jnp.float32)[:, None])
    return u[:n_rows]


def degree_normalized_matvec(
    a: jax.Array,
    v: jax.Array,
    d: jax.Array,
    *,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """u = (A @ v) / d — the r=1 column of the fused mat-mat kernel."""
    return degree_normalized_matmat(
        a, v[:, None], d, tm=tm, tn=tn, interpret=interpret
    )[:, 0]


def power_step(
    a: jax.Array, v: jax.Array, d: jax.Array, *, tm: int = 256, tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Full paper power step: V_{t+1} = (W V) / ||W V||_1 with W = D^-1 A.

    Accepts v of shape (n,) or (n, r); the L1 normalization is per column.
    """
    if v.ndim == 1:
        u = degree_normalized_matvec(a, v, d, tm=tm, tn=tn, interpret=interpret)
        return u / jnp.maximum(jnp.sum(jnp.abs(u)), 1e-30)
    u = degree_normalized_matmat(a, v, d, tm=tm, tn=tn, interpret=interpret)
    return u / jnp.maximum(jnp.sum(jnp.abs(u), axis=0, keepdims=True), 1e-30)
