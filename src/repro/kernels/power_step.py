"""Pallas TPU kernel: fused power-iteration step.

TPU adaptation of the paper's ``Multiply`` + ``Reduction`` + ``Norm`` CUDA
kernels (DESIGN.md §2). Computes in ONE sweep of A:

    u = (A @ v) / d          (the degree-normalized matvec — note that
                              W v = (D^-1 A) v = D^-1 (A v), so W is never
                              materialized: the paper's NormMatrix kernel
                              and its O(n^2) extra read+write disappear — O1b)
    partial L1 mass of u     (per row-tile, combined on the VPU afterwards)

The final scalar division v_{t+1} = u / ||u||_1 is an O(n) epilogue outside
the kernel (the tiny combine the paper does with its tree-Reduction kernel;
on TPU this is a trivial jnp.sum — the CUDA interleaved-addressing pattern
has no TPU analogue, see DESIGN.md §8).

Grid: (n/TM, n/TN), accumulating the matvec across the col-grid dimension j
(TPU grid order is sequential, minor-to-major, so revisiting the same output
block is the idiomatic accumulation pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _power_step_kernel(a_ref, v_ref, d_ref, u_ref, *, nj: int):
    j = pl.program_id(1)

    a = a_ref[...]                       # (TM, TN) tile of A
    v = v_ref[...]                       # (TN, 1) slice of v
    partial = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                    # (TM, 1)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        u_ref[...] += partial

    # last col-step: normalize the accumulated row block by the degree
    @pl.when(j == nj - 1)
    def _norm():
        d = d_ref[...]                   # (TM, 1)
        u_ref[...] = u_ref[...] / jnp.maximum(d, 1e-30)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def degree_normalized_matvec(
    a: jax.Array,
    v: jax.Array,
    d: jax.Array,
    *,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """u = (A @ v) / d, one fused HBM sweep of A. Shapes: (n,n), (n,), (n,)."""
    n = a.shape[0]
    blk = max(tm, tn)
    n_pad = pl.cdiv(n, blk) * blk
    if n_pad != n:
        a = jnp.pad(a, ((0, n_pad - n), (0, n_pad - n)))
        v = jnp.pad(v, (0, n_pad - n))
        d = jnp.pad(d, (0, n_pad - n), constant_values=1.0)

    grid = (n_pad // tm, n_pad // tn)
    u = pl.pallas_call(
        functools.partial(_power_step_kernel, nj=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        interpret=interpret,
    )(a.astype(a.dtype), v.astype(jnp.float32)[:, None],
      d.astype(jnp.float32)[:, None])
    return u[:n, 0]


def power_step(
    a: jax.Array, v: jax.Array, d: jax.Array, *, tm: int = 256, tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Full paper power step: v_{t+1} = (W v) / ||W v||_1 with W = D^-1 A."""
    u = degree_normalized_matvec(a, v, d, tm=tm, tn=tn, interpret=interpret)
    return u / jnp.maximum(jnp.sum(jnp.abs(u)), 1e-30)
