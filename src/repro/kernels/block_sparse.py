"""Pallas TPU kernels: block-CSR stripe sweeps over live affinity tiles.

kNN truncation (DESIGN.md §11) zeroes ~97% of A at knn30/n=1024, but until
this PR every sweep still visited the zero tiles: the dense grid walks
(R/TM)·(C/TN) steps regardless of sparsity, so sweep bandwidth tracked n²
instead of nnz. This module adds the block-CSR counterpart of each sweep
kernel (DESIGN.md §13): after the build, the caller derives a *block plan* —
per row-block, the ascending list of column-block indices with at least one
surviving entry — and the kernels iterate ONLY live blocks.

The plan rides in as scalar-prefetch SMEM operands (`PrefetchScalarGridSpec`):

  counts   (nI,)     int32   live column-blocks in row-block i
  col_idx  (nI, nJ)  int32   ascending live block ids first; the tail is
                             padded with the remaining (dead) ids so every
                             entry stays a valid block index for the DMA
                             index maps even on skipped steps
  max_b    scalar    int32   max(counts) (≥ 1), the traced second grid dim

The grid is (nI, max_b): step (i, j) gathers block `col_idx[i, j]` via the
BlockSpec index maps and accumulates its partial. Ragged tail steps
(j >= counts[i]) gather a DEAD block — all-zero by construction — whose
partial is an exact zero, so no per-step liveness gate is needed: the step
program stays IDENTICAL to the dense kernels' (dot outside any
conditional, assign-at-0/accumulate split, pinned floored divide), which
is what keeps the block-sparse sweeps bitwise-equal to their dense-storage
counterparts at matching tile sizes (asserted in
tests/test_block_sparse.py; nesting the dot inside a pl.when perturbs
interpret-mode XLA fusion at r=1). max_b is a *traced* grid dimension: one
compiled program serves every sparsity pattern, and on hardware the DMA
volume (the real cost) scales with nnz blocks.

Three sweep variants mirror the dense kernels they shadow:

  block_sparse_matmat             kernels/power_step.degree_normalized_matmat
  block_sparse_streaming_matmat   kernels/streaming.affinity_matmat
  block_sparse_streaming_degree   kernels/streaming.affinity_degree_streaming

plus `block_liveness`, the A-free plan *source* for streaming engines: a
full-grid pass that regenerates each masked tile from the feature slabs
(the shared `_masked_tile` body) and emits the (nI, nJ) 0/1 live-block map
without ever materializing A. Explicit engines read liveness off the stored
matrix instead (core/affinity.py::dense_block_live).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .affinity import policy_specs_and_operands, unpack_policy_refs
from .streaming import _masked_tile


def _prefetch_policy_specs(scale_r, thr, *, tm, tn):
    """Block-sparse twins of the policy specs: same operand ORDER and
    padding as kernels/affinity.py::policy_specs_and_operands (which
    callers still use to build the padded operands), but with
    scalar-prefetch-aware index maps — the column-side scale block follows
    the gathered block id col[i, j], not the grid coordinate j."""
    specs = []
    if scale_r is not None:
        specs += [
            pl.BlockSpec((tm, 1), lambda i, j, off, cnt, col: (i, 0)),
            pl.BlockSpec((tn, 1), lambda i, j, off, cnt, col: (col[i, j], 0)),
        ]
    if thr is not None:
        specs.append(pl.BlockSpec((tm, 1), lambda i, j, off, cnt, col: (i, 0)))
    return specs


def _bs_matmat_kernel(cnt_ref, col_ref, a_ref, v_ref, d_ref, u_ref):
    del cnt_ref  # ragged tail steps gather DEAD (all-zero) blocks whose
    del col_ref  # partials are exact zeros — no per-step gate needed, and
    # keeping the step program IDENTICAL to _power_step_kernel (dot outside
    # any conditional, assign-at-0/accumulate split, pinned floored divide)
    # is what keeps the sweep bitwise-equal to the dense kernel: nesting
    # the dot inside a pl.when perturbs interpret-mode XLA fusion at r=1
    # (the same discipline that pins the divide form, DESIGN.md §12)
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    a = a_ref[...].astype(jnp.float32)
    partial = jax.lax.dot_general(
        a, v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        u_ref[...] += partial

    @pl.when(j == nb - 1)
    def _norm():
        u_ref[...] = u_ref[...] / jnp.maximum(d_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def block_sparse_matmat(
    a: jax.Array,
    v: jax.Array,
    d: jax.Array,
    counts: jax.Array,
    col_idx: jax.Array,
    max_b: jax.Array,
    *,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """U = (A @ V) / d visiting only the live blocks of the stored A.

    ``a`` is the (R, C) truncated matrix exactly as the dense path stores
    it (zeros in-tile); the plan (``counts``/``col_idx``/``max_b``, from
    core/affinity.py::block_plan over the same tile grid) tells each
    row-block which column tiles survive. Bitwise-equal to
    degree_normalized_matmat at matching (tm, tn).
    """
    n_rows, n_cols = a.shape
    r = v.shape[1]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    ap = jnp.pad(a, ((0, rp - n_rows), (0, cp - n_cols)))
    vp = jnp.pad(v.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    dp = jnp.pad(d.astype(jnp.float32), (0, rp - n_rows),
                 constant_values=1.0)[:, None]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(rp // tm, jnp.maximum(max_b, 1)),
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j, cnt, col: (i, col[i, j])),
            pl.BlockSpec((tn, r), lambda i, j, cnt, col: (col[i, j], 0)),
            pl.BlockSpec((tm, 1), lambda i, j, cnt, col: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, r), lambda i, j, cnt, col: (i, 0)),
    )
    u = pl.pallas_call(
        _bs_matmat_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rp, r), jnp.float32),
        interpret=interpret,
    )(counts, col_idx, ap, vp, dp)
    return u[:n_rows]


def _bs_streaming_kernel(
    off_ref, cnt_ref, col_ref,
    *refs,
    kind: str, n_rows: int, n_cols: int, tm: int, tn: int,
    inv_two_sigma_sq: float, normalize: bool,
    adaptive: bool, truncate: bool,
):
    refs = list(refs)
    u_ref = refs[-1]
    xr_ref, xc_ref, sqr_ref, sqc_ref, v_ref, d_ref = refs[:6]
    rest = refs[6:-1]
    sclr_ref = sclc_ref = thr_ref = None
    if adaptive:
        sclr_ref, sclc_ref = rest[0], rest[1]
        rest = rest[2:]
    if truncate:
        thr_ref = rest[0]

    del cnt_ref  # ragged tail steps regenerate DEAD tiles — every entry is
    # below its row threshold, so the masked tile and its partial are exact
    # zeros; no per-step gate, and the step program mirrors
    # streaming._streaming_kernel exactly (dot outside any conditional) to
    # stay bitwise-equal to the dense-grid sweep (see _bs_matmat_kernel)
    i = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    # the gathered col-block id drives the diagonal/padding mask — the
    # shared tile body takes it in place of the grid coordinate
    a = _masked_tile(i, col_ref[i, j], off_ref,
                     xr_ref, xc_ref, sqr_ref, sqc_ref,
                     sclr_ref, sclc_ref, thr_ref,
                     kind=kind, n_rows=n_rows, n_cols=n_cols,
                     tm=tm, tn=tn, inv_two_sigma_sq=inv_two_sigma_sq,
                     adaptive=adaptive, truncate=truncate)
    partial = jax.lax.dot_general(
        a, v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        u_ref[...] += partial

    if normalize:
        @pl.when(j == nb - 1)
        def _norm():
            u_ref[...] = u_ref[...] / jnp.maximum(d_ref[...], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret"),
)
def block_sparse_streaming_matmat(
    x: jax.Array,
    v: jax.Array,
    d: jax.Array | None = None,
    xc: jax.Array | None = None,
    *,
    counts: jax.Array,
    col_idx: jax.Array,
    max_b: jax.Array,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> jax.Array:
    """U = (A @ V) / d regenerating ONLY the live feature tiles.

    The A-free twin of block_sparse_matmat: same signature contract as
    kernels/streaming.affinity_matmat plus the block plan (for streaming
    engines the plan comes from `block_liveness`, not a stored matrix).
    ``d=None`` skips normalization and returns partial stripe sums — the
    sharded ring accumulates those across stages, slicing its per-stage
    plan out of a stacked (P, nI, nJ) liveness ring.
    """
    if xc is None:
        xc = x
    adaptive = scale_r is not None
    truncate = thr is not None
    if adaptive and (kind != "rbf" or scale_c is None):
        raise ValueError("adaptive scaling needs kind='rbf' and both "
                         "scale_r and scale_c")
    n_rows, m = x.shape
    n_cols = xc.shape[0]
    r = v.shape[1]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    normalize = d is not None
    if d is None:
        d = jnp.ones((n_rows,), jnp.float32)
    xr32 = jnp.pad(x.astype(jnp.float32), ((0, rp - n_rows), (0, 0)))
    xc32 = jnp.pad(xc.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    vp = jnp.pad(v.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    dp = jnp.pad(d.astype(jnp.float32), (0, rp - n_rows), constant_values=1.0)
    sqr = jnp.sum(xr32 * xr32, axis=1, keepdims=True)
    sqc = jnp.sum(xc32 * xc32, axis=1, keepdims=True)
    off = jnp.array([row_offset, col_offset], jnp.int32).reshape(1, 2)

    kernel = functools.partial(
        _bs_streaming_kernel,
        kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        normalize=normalize, adaptive=adaptive, truncate=truncate,
    )
    in_specs = [
        pl.BlockSpec((tm, m), lambda i, j, off, cnt, col: (i, 0)),
        pl.BlockSpec((tn, m), lambda i, j, off, cnt, col: (col[i, j], 0)),
        pl.BlockSpec((tm, 1), lambda i, j, off, cnt, col: (i, 0)),
        pl.BlockSpec((tn, 1), lambda i, j, off, cnt, col: (col[i, j], 0)),
        pl.BlockSpec((tn, r), lambda i, j, off, cnt, col: (col[i, j], 0)),
        pl.BlockSpec((tm, 1), lambda i, j, off, cnt, col: (i, 0)),
    ]
    _, pol_ops = policy_specs_and_operands(
        scale_r, scale_c, thr, tm=tm, tn=tn, rp=rp, cp=cp,
        n_rows=n_rows, n_cols=n_cols)
    pol_specs = _prefetch_policy_specs(scale_r, thr, tm=tm, tn=tn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(rp // tm, jnp.maximum(max_b, 1)),
        in_specs=in_specs + pol_specs,
        out_specs=pl.BlockSpec((tm, r), lambda i, j, off, cnt, col: (i, 0)),
    )
    u = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rp, r), jnp.float32),
        interpret=interpret,
    )(off, counts, col_idx, xr32, xc32, sqr, sqc, vp, dp[:, None], *pol_ops)
    return u[:n_rows]


def _bs_degree_kernel(
    off_ref, cnt_ref, col_ref,
    *refs,
    kind: str, n_rows: int, n_cols: int, tm: int, tn: int,
    inv_two_sigma_sq: float, adaptive: bool, truncate: bool,
):
    refs = list(refs)
    d_ref = refs[-1]
    xr_ref, xc_ref, sqr_ref, sqc_ref = refs[:4]
    rest = refs[4:-1]
    sclr_ref = sclc_ref = thr_ref = None
    if adaptive:
        sclr_ref, sclc_ref = rest[0], rest[1]
        rest = rest[2:]
    if truncate:
        thr_ref = rest[0]

    del cnt_ref  # dead tail tiles row-sum to exact zero; same pinned step
    # structure as streaming._streaming_degree_kernel (see _bs_matmat_kernel)
    i = pl.program_id(0)
    j = pl.program_id(1)

    a = _masked_tile(i, col_ref[i, j], off_ref,
                     xr_ref, xc_ref, sqr_ref, sqc_ref,
                     sclr_ref, sclc_ref, thr_ref,
                     kind=kind, n_rows=n_rows, n_cols=n_cols,
                     tm=tm, tn=tn, inv_two_sigma_sq=inv_two_sigma_sq,
                     adaptive=adaptive, truncate=truncate)
    partial = jnp.sum(a, axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        d_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret"),
)
def block_sparse_streaming_degree(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    counts: jax.Array,
    col_idx: jax.Array,
    max_b: jax.Array,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> jax.Array:
    """Degree stripe over live blocks only — the block-sparse twin of
    kernels/streaming.affinity_degree_streaming. Bitwise-equal to it
    because skipped tiles are all-zero and contribute exact +0 partials
    to the nonnegative row-sum accumulation."""
    if xc is None:
        xc = x
    adaptive = scale_r is not None
    truncate = thr is not None
    if adaptive and (kind != "rbf" or scale_c is None):
        raise ValueError("adaptive scaling needs kind='rbf' and both "
                         "scale_r and scale_c")
    n_rows, m = x.shape
    n_cols = xc.shape[0]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    xr32 = jnp.pad(x.astype(jnp.float32), ((0, rp - n_rows), (0, 0)))
    xc32 = jnp.pad(xc.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    sqr = jnp.sum(xr32 * xr32, axis=1, keepdims=True)
    sqc = jnp.sum(xc32 * xc32, axis=1, keepdims=True)
    off = jnp.array([row_offset, col_offset], jnp.int32).reshape(1, 2)

    kernel = functools.partial(
        _bs_degree_kernel,
        kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        adaptive=adaptive, truncate=truncate,
    )
    in_specs = [
        pl.BlockSpec((tm, m), lambda i, j, off, cnt, col: (i, 0)),
        pl.BlockSpec((tn, m), lambda i, j, off, cnt, col: (col[i, j], 0)),
        pl.BlockSpec((tm, 1), lambda i, j, off, cnt, col: (i, 0)),
        pl.BlockSpec((tn, 1), lambda i, j, off, cnt, col: (col[i, j], 0)),
    ]
    _, pol_ops = policy_specs_and_operands(
        scale_r, scale_c, thr, tm=tm, tn=tn, rp=rp, cp=cp,
        n_rows=n_rows, n_cols=n_cols)
    pol_specs = _prefetch_policy_specs(scale_r, thr, tm=tm, tn=tn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(rp // tm, jnp.maximum(max_b, 1)),
        in_specs=in_specs + pol_specs,
        out_specs=pl.BlockSpec((tm, 1), lambda i, j, off, cnt, col: (i, 0)),
    )
    d = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        interpret=interpret,
    )(off, counts, col_idx, xr32, xc32, sqr, sqc, *pol_ops)
    return d[:n_rows, 0]


def _liveness_kernel(
    off_ref,
    *refs,
    kind: str, n_rows: int, n_cols: int, tm: int, tn: int,
    inv_two_sigma_sq: float, adaptive: bool, truncate: bool,
):
    refs = list(refs)
    o_ref = refs[-1]
    xr_ref, xc_ref, sqr_ref, sqc_ref = refs[:4]
    sclr_ref, sclc_ref, thr_ref, _ = unpack_policy_refs(
        refs[4:-1], adaptive, truncate)

    i = pl.program_id(0)
    j = pl.program_id(1)
    a = _masked_tile(i, j, off_ref, xr_ref, xc_ref, sqr_ref, sqc_ref,
                     sclr_ref, sclc_ref, thr_ref,
                     kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
                     inv_two_sigma_sq=inv_two_sigma_sq,
                     adaptive=adaptive, truncate=truncate)
    o_ref[...] = jnp.any(a != 0.0).astype(jnp.int32).reshape(1, 1)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret"),
)
def block_liveness(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> jax.Array:
    """(nI, nJ) int32 live-block map of the masked stripe, A-free.

    One full-grid pass (this is build-time work, paid once) regenerating
    each masked tile through the SAME `_masked_tile` body the streaming
    sweeps use, so liveness is exact for the tiles those sweeps would
    compute: live[i, j] = 1 iff any entry of the masked tile is nonzero.
    """
    if xc is None:
        xc = x
    adaptive = scale_r is not None
    truncate = thr is not None
    if adaptive and (kind != "rbf" or scale_c is None):
        raise ValueError("adaptive scaling needs kind='rbf' and both "
                         "scale_r and scale_c")
    n_rows, m = x.shape
    n_cols = xc.shape[0]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    xr32 = jnp.pad(x.astype(jnp.float32), ((0, rp - n_rows), (0, 0)))
    xc32 = jnp.pad(xc.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    sqr = jnp.sum(xr32 * xr32, axis=1, keepdims=True)
    sqc = jnp.sum(xc32 * xc32, axis=1, keepdims=True)
    off = jnp.array([row_offset, col_offset], jnp.int32).reshape(1, 2)

    grid = (rp // tm, cp // tn)
    kernel = functools.partial(
        _liveness_kernel,
        kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        adaptive=adaptive, truncate=truncate,
    )
    in_specs = [
        pl.BlockSpec((1, 2), lambda i, j: (0, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((tm, m), lambda i, j: (i, 0)),
        pl.BlockSpec((tn, m), lambda i, j: (j, 0)),
        pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),
    ]
    operands = [off, xr32, xc32, sqr, sqc]
    pol_specs, pol_ops = policy_specs_and_operands(
        scale_r, scale_c, thr, tm=tm, tn=tn, rp=rp, cp=cp,
        n_rows=n_rows, n_cols=n_cols)
    live = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs + pol_specs,
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.int32),
        interpret=interpret,
    )(*operands, *pol_ops)
    return live
