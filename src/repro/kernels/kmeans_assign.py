"""Pallas TPU kernel: k-means assignment step.

Computes, for a (TM, d) tile of points against the full (k, d) centroid set
held in VMEM, the squared distances on the MXU (expansion form) and the
argmin on the VPU — one read of the points, no (n, k) distance matrix in HBM.

Grid: (n/TM,). Centroids are small (k ≤ a few hundred), so they live in VMEM
for every grid step. k is padded to the 128-lane boundary with +inf distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, csq_ref, lab_ref, dist_ref, *, k: int):
    x = x_ref[...]                              # (TM, d)
    c = c_ref[...]                              # (Kp, d)
    csq = csq_ref[...]                          # (1, Kp)

    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (TM, 1)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (TM, Kp)
    d2 = xx + csq - 2.0 * xc

    kp = c.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(col < k, d2, jnp.inf)        # mask centroid padding

    lab_ref[...] = jnp.argmin(d2, axis=1, keepdims=True).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def kmeans_assign(
    x: jax.Array,
    cents: jax.Array,
    *,
    tm: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (labels (n,) int32, sq-dists (n,) f32) for points x (n, d)."""
    n, dim = x.shape
    k = cents.shape[0]
    kp = max(8, pl.cdiv(k, 8) * 8)
    n_pad = pl.cdiv(n, tm) * tm

    xp = jnp.pad(x.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    cp = jnp.pad(cents.astype(jnp.float32), ((0, kp - k), (0, 0)))
    csq = jnp.sum(cp * cp, axis=1)[None, :]     # (1, Kp)

    labels, dists = pl.pallas_call(
        functools.partial(_assign_kernel, k=k),
        grid=(n_pad // tm,),
        in_specs=[
            pl.BlockSpec((tm, dim), lambda i: (i, 0)),
            pl.BlockSpec((kp, dim), lambda i: (0, 0)),
            pl.BlockSpec((1, kp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, csq)
    return labels[:n, 0], dists[:n, 0]
