"""Pallas TPU kernel: causal flash attention (online-softmax, GQA-aware).

EXPERIMENTS.md §Perf identifies the (s, s) f32 score chains as the dominant
memory term of every 4k-train / 32k-prefill cell; this kernel computes
attention in ONE HBM sweep of K/V per query block — scores never leave VMEM.

Layout: grid (b·h, nq, nk) with the KV dimension minor (sequential on TPU),
carrying the online-softmax state (m, l, acc) in VMEM scratch across the
nk steps of each (bh, iq) program:

    m' = max(m, rowmax(S))          S = Q_blk K_blkᵀ · scale  (MXU)
    l' = l·e^{m-m'} + rowsum(e^{S-m'})
    acc' = acc·e^{m-m'} + e^{S-m'} V_blk
    out  = acc / l                  (epilogue, at ik == nk-1)

GQA: query heads are grouped; the K/V BlockSpec index_map divides the
grid's bh coordinate by the group size, so kv heads are never repeated in
HBM (matches opt H1 of the jnp path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, bq: int, bk: int,
                  seq: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    v = v_ref[0]                                   # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_ids = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_ids = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_ids < seq                              # kv padding
    if causal:
        mask &= k_ids <= q_ids
    s = jnp.where(mask, s, -jnp.inf)

    m_prev = m_scr[...]                             # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # rows with no valid key yet keep m = -inf; guard the exp
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)

    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,           # (bh, s, d)  — batch*heads flattened
    k: jax.Array,           # (bkv, s, d) — batch*kv_heads flattened
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bh, s, d = q.shape
    bkv = k.shape[0]
    assert bh % bkv == 0, "query heads must be a multiple of kv heads"
    rep = bh // bkv
    scale = 1.0 / (d ** 0.5)

    bq = min(block_q, s)
    bk = min(block_k, s)
    s_pad_q = pl.cdiv(s, bq) * bq
    s_pad_k = pl.cdiv(s, bk) * bk
    if s_pad_q != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad_q - s), (0, 0)))
    if s_pad_k != s:
        k = jnp.pad(k, ((0, 0), (0, s_pad_k - s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, s_pad_k - s), (0, 0)))
    nq = s_pad_q // bq
    nk = s_pad_k // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, seq=s, nk=nk),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, rep=rep: (h // rep, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, rep=rep: (h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
