"""Pallas TPU kernel: streaming (A-free) affinity x power-vector fusion.

Computes U = (A @ V) / d WITHOUT ever materializing A (DESIGN.md §5): each
(i, j) grid step regenerates the (TM, TN) affinity tile on the MXU from the
(TM, m) row slab and (TN, m) col slab of the features — exactly the tile the
``affinity_and_degree`` kernel would have written to HBM — applies the
similarity transform and diagonal/padding masks on the VPU, multiplies the
tile by the (TN, r) slice of V, and accumulates the (TM, r) output block.

This is the paper's AffinityMatrix kernel fused INTO the power step: instead
of one O(n^2) write at build time plus an O(n^2) read per iteration, the
engine pays 2 n m reads per tile row/col pass and O(n^2 m / TILE) extra
flops — a bandwidth->compute trade that wins whenever A would spill HBM
(the paper's 36.5 GB matrix at n = 45k) or whenever m << TILE. Unlike the
jnp matrix-free path (cosine kinds only, DESIGN.md §2 O2) this works for
ALL affinity kinds including rbf, because the tile transform is elementwise.

Passing d = ones (or ``affinity_matmat(..., d=None)``) turns off the degree
normalization, which with V = ones((n, 1)) computes the degree vector itself
in one streamed sweep — the RowSum kernel without the matrix.

Grid: (n/TM, n/TN) with n padded to lcm(TM, TN); accumulation over the
col-grid dimension j, same revisit pattern as kernels/power_step.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import round_up_to_lcm


def _streaming_kernel(
    xr_ref, xc_ref, sqr_ref, sqc_ref, v_ref, d_ref,   # inputs
    u_ref,                                            # output
    *, kind: str, n: int, tm: int, tn: int, inv_two_sigma_sq: float, nj: int,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    xr = xr_ref[...]                   # (TM, m) row slab
    xc = xc_ref[...]                   # (TN, m) col slab
    dot = jax.lax.dot_general(
        xr, xc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (TM, TN) affinity tile on the MXU

    if kind == "cosine":
        a = dot
    elif kind == "cosine_shifted":
        a = 0.5 * (1.0 + dot)
    elif kind == "rbf":
        d2 = sqr_ref[...] + sqc_ref[...].T - 2.0 * dot   # (TM,1)+(1,TN)
        a = jnp.exp(-jnp.maximum(d2, 0.0) * inv_two_sigma_sq)
    else:
        raise ValueError(kind)

    rows = i * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    cols = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    valid = (rows != cols) & (rows < n) & (cols < n)
    a = jnp.where(valid, a, 0.0)

    v = v_ref[...]                     # (TN, r) slice of V
    partial = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (TM, r)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        u_ref[...] += partial

    @pl.when(j == nj - 1)
    def _norm():
        d = d_ref[...]                 # (TM, 1)
        u_ref[...] = u_ref[...] / jnp.maximum(d, 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret"),
)
def affinity_matmat(
    x: jax.Array,
    v: jax.Array,
    d: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """U = (A @ V) / d with A regenerated tile-by-tile from features ``x``.

    Shapes: x (n, m), v (n, r), d (n,) or None (no normalization); returns
    (n, r) f32. For the cosine kinds pass L2-row-normalized features; for
    ``rbf`` pass raw features plus the bandwidth ``sigma``. No (n, n) array
    is ever allocated — peak memory is O(n m + n r).
    """
    n, m = x.shape
    r = v.shape[1]
    n_pad = round_up_to_lcm(n, tm, tn)
    if d is None:
        d = jnp.ones((n,), jnp.float32)
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
        v = jnp.pad(v, ((0, n_pad - n), (0, 0)))
        d = jnp.pad(d, (0, n_pad - n), constant_values=1.0)
    x32 = x.astype(jnp.float32)
    sq = jnp.sum(x32 * x32, axis=1, keepdims=True)       # (n_pad, 1)

    grid = (n_pad // tm, n_pad // tn)
    kernel = functools.partial(
        _streaming_kernel,
        kind=kind, n=n, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        nj=grid[1],
    )
    u = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, m), lambda i, j: (i, 0)),   # row slab
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),   # col slab
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # row sq-norms
            pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),   # col sq-norms
            pl.BlockSpec((tn, r), lambda i, j: (j, 0)),   # V slice
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # degree
        ],
        out_specs=pl.BlockSpec((tm, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, r), jnp.float32),
        interpret=interpret,
    )(x32, x32, sq, sq, v.astype(jnp.float32),
      d.astype(jnp.float32)[:, None])
    return u[:n]


def _streaming_degree_kernel(
    xr_ref, xc_ref, sqr_ref, sqc_ref, d_ref,
    *, kind: str, n: int, tm: int, tn: int, inv_two_sigma_sq: float,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    xr = xr_ref[...]
    xc = xc_ref[...]
    dot = jax.lax.dot_general(
        xr, xc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    if kind == "cosine":
        a = dot
    elif kind == "cosine_shifted":
        a = 0.5 * (1.0 + dot)
    elif kind == "rbf":
        d2 = sqr_ref[...] + sqc_ref[...].T - 2.0 * dot
        a = jnp.exp(-jnp.maximum(d2, 0.0) * inv_two_sigma_sq)
    else:
        raise ValueError(kind)

    rows = i * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    cols = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    valid = (rows != cols) & (rows < n) & (cols < n)
    a = jnp.where(valid, a, 0.0)

    # identical VPU reduction to the fused RowSum in kernels/affinity.py, so
    # the streaming engine's degrees (and hence its whole power trajectory)
    # are bitwise-equal to the explicit-A engine's at matching tile sizes
    partial = jnp.sum(a, axis=1, keepdims=True)          # (TM, 1)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        d_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret"),
)
def affinity_degree_streaming(
    x: jax.Array,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Degree vector D = A @ 1 in one streamed sweep — the paper's
    AffinityMatrix + RowSum fusion (O1a) without the O(n^2) A write."""
    n, m = x.shape
    n_pad = round_up_to_lcm(n, tm, tn)
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    x32 = x.astype(jnp.float32)
    sq = jnp.sum(x32 * x32, axis=1, keepdims=True)

    grid = (n_pad // tm, n_pad // tn)
    kernel = functools.partial(
        _streaming_degree_kernel,
        kind=kind, n=n, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
    )
    d = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, m), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        interpret=interpret,
    )(x32, x32, sq, sq)
    return d[:n, 0]
