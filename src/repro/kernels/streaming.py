"""Pallas TPU kernel: streaming (A-free) affinity x power-vector fusion.

Computes U = (A @ V) / d WITHOUT ever materializing A (DESIGN.md §5): each
(i, j) grid step regenerates the (TM, TN) affinity tile on the MXU from the
(TM, m) row slab and (TN, m) col slab of the features — exactly the tile the
``affinity_and_degree`` kernel would have written to HBM — applies the
similarity transform and diagonal/padding masks on the VPU, multiplies the
tile by the (TN, r) slice of V, and accumulates the (TM, r) output block.

This is the paper's AffinityMatrix kernel fused INTO the power step: instead
of one O(n^2) write at build time plus an O(n^2) read per iteration, the
engine pays 2 n m reads per tile row/col pass and O(n^2 m / TILE) extra
flops — a bandwidth->compute trade that wins whenever A would spill HBM
(the paper's 36.5 GB matrix at n = 45k) or whenever m << TILE. Unlike the
jnp matrix-free path (cosine kinds only, DESIGN.md §2 O2) this works for
ALL affinity kinds including rbf, because the tile transform is elementwise.

Like the explicit build, the kernels compute a general *stripe*: row
features ``x`` (R, m) against col features ``xc`` (C, m) with global
``row_offset``/``col_offset`` locating the diagonal to mask (traced SMEM
scalars — one compiled program serves every shard position). The sharded
streaming ring (DESIGN.md §9) calls this once per ring stage with the
feature block that just arrived over the mesh, so each device's peak
memory stays O(n·m/P).

The graph-construction policies (DESIGN.md §11) stream exactly like the
explicit build: adaptive local scales ride in as (·, 1) blocks next to the
squared norms and swap the tile transform to exp(-d²/(σᵢσⱼ)); the per-row
truncation threshold merges into the validity mask, so truncated entries
contribute exact zeros to the product/degrees — the streamed sweep and the
explicit masked matrix stay bitwise-consistent at matching tile sizes.

Passing d = ones (or ``affinity_matmat(..., d=None)``) turns off the degree
normalization, which with V = ones((n, 1)) computes the degree vector itself
in one streamed sweep — the RowSum kernel without the matrix. ``d=None``
also leaves the output un-normalized for callers that accumulate partial
stripes (the ring) and divide once at the end.

Grid: (R/TM, C/TN) with rows/cols padded to TM/TN multiples independently;
accumulation over the col-grid dimension j, same revisit pattern as
kernels/power_step.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .affinity import (
    affinity_tile_transform,
    policy_specs_and_operands,
    tile_masks,
    unpack_policy_refs,
)


def _masked_tile(i, j, off_ref, xr_ref, xc_ref, sqr_ref, sqc_ref,
                 sclr_ref, sclc_ref, thr_ref, thr_c_ref=None,
                 *, kind, n_rows, n_cols, tm, tn, inv_two_sigma_sq,
                 adaptive, truncate, truncate_col=False):
    """Regenerate the masked affinity tile — the shared body of both
    streaming kernels (and their block-sparse variants, which pass the
    gathered col-block id as ``j``), matching kernels/affinity.py
    op-for-op. ``thr_c_ref`` applies the COLUMN's own row threshold
    (the transpose mask used by the Aᵀ reachability product — exact
    because the score transform is symmetric in its arguments)."""
    xr = xr_ref[...]                   # (TM, m) row slab
    xc = xc_ref[...]                   # (TN, m) col slab
    dot = jax.lax.dot_general(
        xr, xc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (TM, TN) affinity tile on the MXU

    a = affinity_tile_transform(
        dot, sqr_ref[...] if kind == "rbf" else None,
        sqc_ref[...] if kind == "rbf" else None,
        kind=kind, inv_two_sigma_sq=inv_two_sigma_sq,
        sclr=sclr_ref[...] if adaptive else None,
        sclc=sclc_ref[...] if adaptive else None,
    )

    valid = tile_masks(i, j, off_ref, tm=tm, tn=tn,
                       n_rows=n_rows, n_cols=n_cols)
    if truncate:
        valid = valid & (a >= thr_ref[...])              # (TM, 1) broadcast
    if truncate_col:
        valid = valid & (a >= thr_c_ref[...].T)          # (1, TN) broadcast
    return jnp.where(valid, a, 0.0)


def _streaming_kernel(
    off_ref,                                          # (1, 2) SMEM offsets
    *refs,
    kind: str, n_rows: int, n_cols: int, tm: int, tn: int,
    inv_two_sigma_sq: float, nj: int, normalize: bool,
    adaptive: bool, truncate: bool, truncate_col: bool,
):
    refs = list(refs)
    u_ref = refs[-1]
    xr_ref, xc_ref, sqr_ref, sqc_ref, v_ref, d_ref = refs[:6]
    sclr_ref, sclc_ref, thr_ref, thr_c_ref = unpack_policy_refs(
        refs[6:-1], adaptive, truncate, truncate_col)

    i = pl.program_id(0)
    j = pl.program_id(1)

    a = _masked_tile(i, j, off_ref, xr_ref, xc_ref, sqr_ref, sqc_ref,
                     sclr_ref, sclc_ref, thr_ref, thr_c_ref,
                     kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
                     inv_two_sigma_sq=inv_two_sigma_sq,
                     adaptive=adaptive, truncate=truncate,
                     truncate_col=truncate_col)

    v = v_ref[...]                     # (TN, r) slice of V
    partial = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (TM, r)

    @pl.when(j == 0)
    def _init():
        u_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        u_ref[...] += partial

    if normalize:
        @pl.when(j == nj - 1)
        def _norm():
            # floored divide, zero-degree safe as-is: d = 0 implies the
            # whole (nonnegative) A row is zero, so the accumulated u row
            # is an exact 0 and stays 0; NaN degrees propagate to the
            # loop's non-finite latch (DESIGN.md §12). The divide form is
            # pinned — masked-where variants perturb interpret-mode XLA
            # fusion and break local/sharded trajectory parity (the
            # kernels/ops.py::_tiles discipline). Padding rows carry
            # d = 1.0.
            d = d_ref[...]                 # (TM, 1)
            u_ref[...] = u_ref[...] / jnp.maximum(d, 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret"),
)
def affinity_matmat(
    x: jax.Array,
    v: jax.Array,
    d: jax.Array | None = None,
    xc: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
    thr_c: jax.Array | None = None,
) -> jax.Array:
    """U = (A @ V) / d with A regenerated tile-by-tile from features.

    Shapes: x (R, m) row features, xc (C, m) col features (None — the
    square self-stripe xc = x), v (C, r), d (R,) or None (no
    normalization); returns (R, r) f32. The offsets locate the stripe in
    the global matrix for the diagonal mask. For the cosine kinds pass
    L2-row-normalized features; for ``rbf`` pass raw features plus the
    bandwidth ``sigma``. ``scale_r``/``scale_c`` (R,)/(C,) switch rbf to
    adaptive local scaling; ``thr`` (R,) truncates rows below their pass-1
    threshold (DESIGN.md §11). ``thr_c`` (C,) instead applies each COLUMN's
    own threshold — Aᵀ[stripe] @ V for the symmetrized reachability probe
    (score symmetry makes the column-side mask the exact transpose
    pattern). No (R, C) array is ever allocated — peak memory is
    O((R + C)·m + (R + C)·r).
    """
    if xc is None:
        xc = x
    adaptive = scale_r is not None
    truncate = thr is not None
    truncate_col = thr_c is not None
    if adaptive and (kind != "rbf" or scale_c is None):
        raise ValueError("adaptive scaling needs kind='rbf' and both "
                         "scale_r and scale_c")
    n_rows, m = x.shape
    n_cols = xc.shape[0]
    r = v.shape[1]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    normalize = d is not None
    if d is None:
        d = jnp.ones((n_rows,), jnp.float32)
    xr32 = jnp.pad(x.astype(jnp.float32), ((0, rp - n_rows), (0, 0)))
    xc32 = jnp.pad(xc.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    vp = jnp.pad(v.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    dp = jnp.pad(d.astype(jnp.float32), (0, rp - n_rows), constant_values=1.0)
    sqr = jnp.sum(xr32 * xr32, axis=1, keepdims=True)    # (rp, 1)
    sqc = jnp.sum(xc32 * xc32, axis=1, keepdims=True)    # (cp, 1)
    off = jnp.array([row_offset, col_offset], jnp.int32).reshape(1, 2)

    grid = (rp // tm, cp // tn)
    kernel = functools.partial(
        _streaming_kernel,
        kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        nj=grid[1], normalize=normalize,
        adaptive=adaptive, truncate=truncate, truncate_col=truncate_col,
    )
    in_specs = [
        pl.BlockSpec((1, 2), lambda i, j: (0, 0),
                     memory_space=pltpu.SMEM),        # global offsets
        pl.BlockSpec((tm, m), lambda i, j: (i, 0)),   # row slab
        pl.BlockSpec((tn, m), lambda i, j: (j, 0)),   # col slab
        pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # row sq-norms
        pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),   # col sq-norms
        pl.BlockSpec((tn, r), lambda i, j: (j, 0)),   # V slice
        pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # degree
    ]
    operands = [off, xr32, xc32, sqr, sqc, vp, dp[:, None]]
    pol_specs, pol_ops = policy_specs_and_operands(
        scale_r, scale_c, thr, thr_c, tm=tm, tn=tn, rp=rp, cp=cp,
        n_rows=n_rows, n_cols=n_cols)
    u = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs + pol_specs,
        out_specs=pl.BlockSpec((tm, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, r), jnp.float32),
        interpret=interpret,
    )(*operands, *pol_ops)
    return u[:n_rows]


def _streaming_degree_kernel(
    off_ref,
    *refs,
    kind: str, n_rows: int, n_cols: int, tm: int, tn: int,
    inv_two_sigma_sq: float, adaptive: bool, truncate: bool,
):
    refs = list(refs)
    d_ref = refs[-1]
    xr_ref, xc_ref, sqr_ref, sqc_ref = refs[:4]
    sclr_ref, sclc_ref, thr_ref, _ = unpack_policy_refs(
        refs[4:-1], adaptive, truncate)

    i = pl.program_id(0)
    j = pl.program_id(1)

    a = _masked_tile(i, j, off_ref, xr_ref, xc_ref, sqr_ref, sqc_ref,
                     sclr_ref, sclc_ref, thr_ref,
                     kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
                     inv_two_sigma_sq=inv_two_sigma_sq,
                     adaptive=adaptive, truncate=truncate)

    # identical VPU reduction to the fused RowSum in kernels/affinity.py, so
    # the streaming engine's degrees (and hence its whole power trajectory)
    # are bitwise-equal to the explicit-A engine's at matching tile sizes
    partial = jnp.sum(a, axis=1, keepdims=True)          # (TM, 1)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        d_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret"),
)
def affinity_degree_streaming(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> jax.Array:
    """Degree stripe D = A[stripe] @ 1 in one streamed sweep — the paper's
    AffinityMatrix + RowSum fusion (O1a) without the O(n^2) A write. With
    ``xc`` given, returns the partial row sums over that column block only
    (the ring accumulates these across stages). ``scale_r``/``scale_c``/
    ``thr`` apply the adaptive-scaling / truncation policies in-tile."""
    if xc is None:
        xc = x
    adaptive = scale_r is not None
    truncate = thr is not None
    if adaptive and (kind != "rbf" or scale_c is None):
        raise ValueError("adaptive scaling needs kind='rbf' and both "
                         "scale_r and scale_c")
    n_rows, m = x.shape
    n_cols = xc.shape[0]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    xr32 = jnp.pad(x.astype(jnp.float32), ((0, rp - n_rows), (0, 0)))
    xc32 = jnp.pad(xc.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    sqr = jnp.sum(xr32 * xr32, axis=1, keepdims=True)
    sqc = jnp.sum(xc32 * xc32, axis=1, keepdims=True)
    off = jnp.array([row_offset, col_offset], jnp.int32).reshape(1, 2)

    grid = (rp // tm, cp // tn)
    kernel = functools.partial(
        _streaming_degree_kernel,
        kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        adaptive=adaptive, truncate=truncate,
    )
    in_specs = [
        pl.BlockSpec((1, 2), lambda i, j: (0, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((tm, m), lambda i, j: (i, 0)),
        pl.BlockSpec((tn, m), lambda i, j: (j, 0)),
        pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),
    ]
    operands = [off, xr32, xc32, sqr, sqc]
    pol_specs, pol_ops = policy_specs_and_operands(
        scale_r, scale_c, thr, tm=tm, tn=tn, rp=rp, cp=cp,
        n_rows=n_rows, n_cols=n_cols)
    d = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs + pol_specs,
        out_specs=pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        interpret=interpret,
    )(*operands, *pol_ops)
    return d[:n_rows, 0]
