"""Pallas TPU kernel: fused affinity-matrix + degree construction.

TPU adaptation of the paper's ``AffinityMatrix`` + ``RowSum`` CUDA kernels
(DESIGN.md §2). One HBM sweep produces both the (n, n) affinity tile grid and
the degree vector D — the paper's separate RowSum kernel (an extra O(n²) read)
is fused into the tile epilogue (optimization O1a).

Grid: (n/TM, n/TN); each step loads a (TM, m) row-slab and a (TN, m) col-slab
of the (row-normalized) input into VMEM, runs the (TM, m)·(m, TN) product on
the MXU, applies the similarity transform on the VPU, masks the diagonal /
padding, writes the A tile, and accumulates the partial row-sums into D.

Tile sizes default to 256×256 (512 KiB f32 per A tile — comfortably inside
a ~16 MiB VMEM budget together with the two input slabs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import round_up_to_lcm


def _affinity_kernel(
    xr_ref, xc_ref, sqr_ref, sqc_ref,  # inputs
    a_ref, d_ref,                      # outputs
    *, kind: str, n: int, tm: int, tn: int, inv_two_sigma_sq: float,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    xr = xr_ref[...]                   # (TM, m) row slab
    xc = xc_ref[...]                   # (TN, m) col slab
    dot = jax.lax.dot_general(
        xr, xc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (TM, TN) on the MXU

    if kind == "cosine":
        a = dot
    elif kind == "cosine_shifted":
        a = 0.5 * (1.0 + dot)
    elif kind == "rbf":
        d2 = sqr_ref[...] + sqc_ref[...].T - 2.0 * dot   # (TM,1)+(1,TN)
        a = jnp.exp(-jnp.maximum(d2, 0.0) * inv_two_sigma_sq)
    else:
        raise ValueError(kind)

    # global row/col ids for diagonal + padding masks
    rows = i * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    cols = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    valid = (rows != cols) & (rows < n) & (cols < n)
    a = jnp.where(valid, a, 0.0)

    a_ref[...] = a.astype(a_ref.dtype)

    # fused RowSum: accumulate partial degrees across the col-grid dimension
    partial = jnp.sum(a, axis=1, keepdims=True)          # (TM, 1)
    @pl.when(j == 0)
    def _init():
        d_ref[...] = partial.astype(d_ref.dtype)

    @pl.when(j != 0)
    def _acc():
        d_ref[...] += partial.astype(d_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret", "out_dtype"),
)
def affinity_and_degree(
    xn: jax.Array,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Returns (A (n, n), D (n,)) from pre-normalized features ``xn`` (n, m).

    For ``kind='rbf'`` pass the *raw* features and a bandwidth ``sigma``;
    for the cosine kinds pass L2-row-normalized features.
    """
    n, m = xn.shape
    n_pad = round_up_to_lcm(n, tm, tn)  # both grid dims must divide evenly
    if n_pad != n:
        xn = jnp.pad(xn, ((0, n_pad - n), (0, 0)))
    x32 = xn.astype(jnp.float32)
    sq = jnp.sum(x32 * x32, axis=1, keepdims=True)       # (n_pad, 1)

    grid = (n_pad // tm, n_pad // tn)
    kernel = functools.partial(
        _affinity_kernel,
        kind=kind, n=n, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
    )
    a, d = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, m), lambda i, j: (i, 0)),   # row slab
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),   # col slab
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # row sq-norms
            pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),   # col sq-norms
        ],
        out_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),  # A tile
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # degree (acc over j)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, n_pad), out_dtype),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x32, x32, sq, sq)
    return a[:n, :n], d[:n, 0]
