"""Pallas TPU kernel: fused affinity-matrix + degree construction.

TPU adaptation of the paper's ``AffinityMatrix`` + ``RowSum`` CUDA kernels
(DESIGN.md §2). One HBM sweep produces both the affinity tile grid and the
degree vector D — the paper's separate RowSum kernel (an extra O(n²) read)
is fused into the tile epilogue (optimization O1a).

The kernel computes a general *stripe* A[row_offset:row_offset+R,
col_offset:col_offset+C] from a (R, m) row-feature slab and a (C, m)
col-feature slab (DESIGN.md §9): the single-device build is the square
self-stripe (xc = xn, offsets 0), and the sharded explicit path calls the
SAME kernel on its local row block against the gathered feature matrix.
The global offsets drive the diagonal mask and arrive as traced scalars in
SMEM, so one compiled program serves every shard position.

Grid: (R/TM, C/TN); each step loads a (TM, m) row-slab and a (TN, m)
col-slab into VMEM, runs the (TM, m)·(m, TN) product on the MXU, applies
the similarity transform on the VPU, masks the diagonal / padding, writes
the A tile, and accumulates the partial row-sums into D.

Tile sizes default to 256×256 (512 KiB f32 per A tile — comfortably inside
a ~16 MiB VMEM budget together with the two input slabs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _affinity_kernel(
    off_ref,                           # (1, 2) SMEM: global row/col offsets
    xr_ref, xc_ref, sqr_ref, sqc_ref,  # inputs
    a_ref, d_ref,                      # outputs
    *, kind: str, n_rows: int, n_cols: int, tm: int, tn: int,
    inv_two_sigma_sq: float,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    xr = xr_ref[...]                   # (TM, m) row slab
    xc = xc_ref[...]                   # (TN, m) col slab
    dot = jax.lax.dot_general(
        xr, xc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (TM, TN) on the MXU

    if kind == "cosine":
        a = dot
    elif kind == "cosine_shifted":
        a = 0.5 * (1.0 + dot)
    elif kind == "rbf":
        d2 = sqr_ref[...] + sqc_ref[...].T - 2.0 * dot   # (TM,1)+(1,TN)
        a = jnp.exp(-jnp.maximum(d2, 0.0) * inv_two_sigma_sq)
    else:
        raise ValueError(kind)

    # local row/col ids for the padding masks; global ids (local + the
    # stripe offsets) for the diagonal mask
    lrows = i * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    lcols = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    grows = off_ref[0, 0] + lrows
    gcols = off_ref[0, 1] + lcols
    valid = (grows != gcols) & (lrows < n_rows) & (lcols < n_cols)
    a = jnp.where(valid, a, 0.0)

    a_ref[...] = a.astype(a_ref.dtype)

    # fused RowSum: accumulate partial degrees across the col-grid dimension
    partial = jnp.sum(a, axis=1, keepdims=True)          # (TM, 1)
    @pl.when(j == 0)
    def _init():
        d_ref[...] = partial.astype(d_ref.dtype)

    @pl.when(j != 0)
    def _acc():
        d_ref[...] += partial.astype(d_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret", "out_dtype"),
)
def affinity_and_degree(
    xn: jax.Array,
    xc: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    out_dtype=jnp.float32,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Returns (A (R, C), D (R,)) for the affinity stripe of ``xn`` vs ``xc``.

    ``xc=None`` is the square self-affinity (the paper's build): A is
    (n, n) and D its row sums. With ``xc`` given, A is the
    ``A[row_offset:row_offset+R, col_offset:col_offset+C]`` stripe of the
    global matrix and D its stripe row sums; the offsets (traced scalars
    are fine — they ride in SMEM) locate the global diagonal to mask.

    For ``kind='rbf'`` pass the *raw* features and a bandwidth ``sigma``;
    for the cosine kinds pass L2-row-normalized features.
    """
    if xc is None:
        xc = xn
    n_rows, m = xn.shape
    n_cols = xc.shape[0]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    xr32 = jnp.pad(xn.astype(jnp.float32), ((0, rp - n_rows), (0, 0)))
    xc32 = jnp.pad(xc.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    sqr = jnp.sum(xr32 * xr32, axis=1, keepdims=True)    # (rp, 1)
    sqc = jnp.sum(xc32 * xc32, axis=1, keepdims=True)    # (cp, 1)
    off = jnp.array([row_offset, col_offset], jnp.int32).reshape(1, 2)

    grid = (rp // tm, cp // tn)
    kernel = functools.partial(
        _affinity_kernel,
        kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
    )
    a, d = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),        # global offsets
            pl.BlockSpec((tm, m), lambda i, j: (i, 0)),   # row slab
            pl.BlockSpec((tn, m), lambda i, j: (j, 0)),   # col slab
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # row sq-norms
            pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),   # col sq-norms
        ],
        out_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),  # A tile
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # degree (acc over j)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), out_dtype),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(off, xr32, xc32, sqr, sqc)
    return a[:n_rows, :n_cols], d[:n_rows, 0]
