"""Pallas TPU kernel: fused affinity-matrix + degree construction.

TPU adaptation of the paper's ``AffinityMatrix`` + ``RowSum`` CUDA kernels
(DESIGN.md §2). One HBM sweep produces both the affinity tile grid and the
degree vector D — the paper's separate RowSum kernel (an extra O(n²) read)
is fused into the tile epilogue (optimization O1a).

The kernel computes a general *stripe* A[row_offset:row_offset+R,
col_offset:col_offset+C] from a (R, m) row-feature slab and a (C, m)
col-feature slab (DESIGN.md §9): the single-device build is the square
self-stripe (xc = xn, offsets 0), and the sharded explicit path calls the
SAME kernel on its local row block against the gathered feature matrix.
The global offsets drive the diagonal mask and arrive as traced scalars in
SMEM, so one compiled program serves every shard position.

Graph-construction policies (DESIGN.md §11) are applied in-tile:

- adaptive local scaling (``scale_r``/``scale_c`` given, rbf): the tile
  transform becomes exp(-d² / (σᵢ σⱼ)) from the per-row scale columns —
  the (R,)/(C,) pass-1 statistics ride in as (·, 1) VMEM blocks.
- kNN truncation (``thr`` given): entries below the row's threshold
  τᵢ (the row's knn_k-th largest similarity, pass 1) fold into the same
  validity mask as the diagonal/padding — truncated entries are written as
  exact zeros and never reach the degree accumulation. The mask is free:
  it merges into the one ``jnp.where`` the kernel always executes.

The default dense fixed-bandwidth spec passes no extra operands and
compiles the exact PR-3 program (bitwise-pinned baseline).

Grid: (R/TM, C/TN); each step loads a (TM, m) row-slab and a (TN, m)
col-slab into VMEM, runs the (TM, m)·(m, TN) product on the MXU, applies
the similarity transform on the VPU, masks the diagonal / padding /
truncation, writes the A tile, and accumulates the partial row-sums into D.

Tile sizes default to 256×256 (512 KiB f32 per A tile — comfortably inside
a ~16 MiB VMEM budget together with the two input slabs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def affinity_tile_transform(dot, sqr, sqc, *, kind: str,
                            inv_two_sigma_sq: float,
                            sclr=None, sclc=None):
    """The one similarity transform every GPIC kernel applies to an MXU
    tile: ``dot`` (TM, TN) row·col products, ``sqr``/``sqc`` the (TM, 1) /
    (TN, 1) squared norms (rbf only), ``sclr``/``sclc`` the (TM, 1) /
    (TN, 1) adaptive local scales (rbf + adaptive bandwidth only). Shared
    by the explicit build, both streaming kernels, and the row-top-k pass
    so all paths compute bitwise-identical tile values."""
    if kind == "cosine":
        return dot
    if kind == "cosine_shifted":
        return 0.5 * (1.0 + dot)
    if kind == "rbf":
        d2 = sqr + sqc.T - 2.0 * dot                     # (TM,1)+(1,TN)
        if sclr is not None:
            return jnp.exp(-jnp.maximum(d2, 0.0) / (sclr * sclc.T))
        return jnp.exp(-jnp.maximum(d2, 0.0) * inv_two_sigma_sq)
    raise ValueError(kind)


def tile_masks(i, j, off_ref, *, tm: int, tn: int, n_rows: int, n_cols: int):
    """(valid, ) in-tile mask: local row/col ids bound the padding, the
    global ids (local + the SMEM stripe offsets) locate the diagonal."""
    lrows = i * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    lcols = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    grows = off_ref[0, 0] + lrows
    gcols = off_ref[0, 1] + lcols
    return (grows != gcols) & (lrows < n_rows) & (lcols < n_cols)


def unpack_policy_refs(rest, adaptive: bool, truncate: bool,
                       truncate_col: bool = False):
    """(sclr, sclc, thr, thr_c) refs from a kernel's flag-dependent operand
    tail. Shared by the affinity, streaming, and row-top-k kernels so the
    operand order is defined in exactly one place. ``truncate_col`` is the
    transpose-side mask (a column's OWN row threshold, applied while
    computing Aᵀ products for the reachability probe)."""
    sclr_ref = sclc_ref = thr_ref = thr_c_ref = None
    rest = list(rest)
    if adaptive:
        sclr_ref, sclc_ref = rest[0], rest[1]
        rest = rest[2:]
    if truncate:
        thr_ref = rest[0]
        rest = rest[1:]
    if truncate_col:
        thr_c_ref = rest[0]
        rest = rest[1:]
    assert not rest
    return sclr_ref, sclc_ref, thr_ref, thr_c_ref


def policy_specs_and_operands(scale_r, scale_c, thr, thr_c=None, *, tm, tn,
                              rp, cp, n_rows, n_cols):
    """(in_specs, operands) for the pass-1 policy columns — the ONE
    definition of their padding semantics, which the cross-engine bitwise
    discipline rests on: padded rows carry neutral values (scale 1,
    threshold +inf, so padding masks to exact zeros). ``thr_c`` is the
    (C,) column-side threshold of the transpose mask (padded +inf too)."""
    in_specs, operands = [], []
    if scale_r is not None:
        sclr = jnp.pad(scale_r.astype(jnp.float32), (0, rp - n_rows),
                       constant_values=1.0)[:, None]
        sclc = jnp.pad(scale_c.astype(jnp.float32), (0, cp - n_cols),
                       constant_values=1.0)[:, None]
        in_specs += [pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
                     pl.BlockSpec((tn, 1), lambda i, j: (j, 0))]
        operands += [sclr, sclc]
    if thr is not None:
        thr_p = jnp.pad(thr.astype(jnp.float32), (0, rp - n_rows),
                        constant_values=jnp.inf)[:, None]
        in_specs.append(pl.BlockSpec((tm, 1), lambda i, j: (i, 0)))
        operands.append(thr_p)
    if thr_c is not None:
        thr_cp = jnp.pad(thr_c.astype(jnp.float32), (0, cp - n_cols),
                         constant_values=jnp.inf)[:, None]
        in_specs.append(pl.BlockSpec((tn, 1), lambda i, j: (j, 0)))
        operands.append(thr_cp)
    return in_specs, operands


def _affinity_kernel(
    off_ref,                           # (1, 2) SMEM: global row/col offsets
    *refs,                             # inputs then outputs (flag-dependent)
    kind: str, n_rows: int, n_cols: int, tm: int, tn: int,
    inv_two_sigma_sq: float, adaptive: bool, truncate: bool,
):
    refs = list(refs)
    a_ref, d_ref = refs[-2], refs[-1]
    xr_ref, xc_ref, sqr_ref, sqc_ref = refs[:4]
    sclr_ref, sclc_ref, thr_ref, _ = unpack_policy_refs(
        refs[4:-2], adaptive, truncate)

    i = pl.program_id(0)
    j = pl.program_id(1)

    xr = xr_ref[...]                   # (TM, m) row slab
    xc = xc_ref[...]                   # (TN, m) col slab
    dot = jax.lax.dot_general(
        xr, xc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                  # (TM, TN) on the MXU

    a = affinity_tile_transform(
        dot, sqr_ref[...] if kind == "rbf" else None,
        sqc_ref[...] if kind == "rbf" else None,
        kind=kind, inv_two_sigma_sq=inv_two_sigma_sq,
        sclr=sclr_ref[...] if adaptive else None,
        sclc=sclc_ref[...] if adaptive else None,
    )

    valid = tile_masks(i, j, off_ref, tm=tm, tn=tn,
                       n_rows=n_rows, n_cols=n_cols)
    if truncate:
        valid = valid & (a >= thr_ref[...])              # (TM, 1) broadcast
    a = jnp.where(valid, a, 0.0)

    a_ref[...] = a.astype(a_ref.dtype)

    # fused RowSum: accumulate partial degrees across the col-grid dimension
    partial = jnp.sum(a, axis=1, keepdims=True)          # (TM, 1)
    @pl.when(j == 0)
    def _init():
        d_ref[...] = partial.astype(d_ref.dtype)

    @pl.when(j != 0)
    def _acc():
        d_ref[...] += partial.astype(d_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "sigma", "tm", "tn", "interpret", "out_dtype"),
)
def affinity_and_degree(
    xn: jax.Array,
    xc: jax.Array | None = None,
    *,
    kind: str = "cosine_shifted",
    sigma: float = 1.0,
    tm: int = 256,
    tn: int = 256,
    interpret: bool = False,
    out_dtype=jnp.float32,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    thr: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (A (R, C), D (R,)) for the affinity stripe of ``xn`` vs ``xc``.

    ``xc=None`` is the square self-affinity (the paper's build): A is
    (n, n) and D its row sums. With ``xc`` given, A is the
    ``A[row_offset:row_offset+R, col_offset:col_offset+C]`` stripe of the
    global matrix and D its stripe row sums; the offsets (traced scalars
    are fine — they ride in SMEM) locate the global diagonal to mask.

    For ``kind='rbf'`` pass the *raw* features and a bandwidth ``sigma``;
    for the cosine kinds pass L2-row-normalized features. ``scale_r`` /
    ``scale_c`` (R,)/(C,) switch rbf to adaptive local scaling
    exp(-d²/(σᵢσⱼ)); ``thr`` (R,) truncates each row below its threshold
    (both pass-1 statistics from kernels/row_topk.py, DESIGN.md §11).
    """
    if xc is None:
        xc = xn
    adaptive = scale_r is not None
    truncate = thr is not None
    if adaptive and (kind != "rbf" or scale_c is None):
        raise ValueError("adaptive scaling needs kind='rbf' and both "
                         "scale_r and scale_c")
    n_rows, m = xn.shape
    n_cols = xc.shape[0]
    rp = pl.cdiv(n_rows, tm) * tm
    cp = pl.cdiv(n_cols, tn) * tn
    xr32 = jnp.pad(xn.astype(jnp.float32), ((0, rp - n_rows), (0, 0)))
    xc32 = jnp.pad(xc.astype(jnp.float32), ((0, cp - n_cols), (0, 0)))
    sqr = jnp.sum(xr32 * xr32, axis=1, keepdims=True)    # (rp, 1)
    sqc = jnp.sum(xc32 * xc32, axis=1, keepdims=True)    # (cp, 1)
    off = jnp.array([row_offset, col_offset], jnp.int32).reshape(1, 2)

    grid = (rp // tm, cp // tn)
    kernel = functools.partial(
        _affinity_kernel,
        kind=kind, n_rows=n_rows, n_cols=n_cols, tm=tm, tn=tn,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        adaptive=adaptive, truncate=truncate,
    )
    in_specs = [
        pl.BlockSpec((1, 2), lambda i, j: (0, 0),
                     memory_space=pltpu.SMEM),        # global offsets
        pl.BlockSpec((tm, m), lambda i, j: (i, 0)),   # row slab
        pl.BlockSpec((tn, m), lambda i, j: (j, 0)),   # col slab
        pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # row sq-norms
        pl.BlockSpec((tn, 1), lambda i, j: (j, 0)),   # col sq-norms
    ]
    operands = [off, xr32, xc32, sqr, sqc]
    pol_specs, pol_ops = policy_specs_and_operands(
        scale_r, scale_c, thr, tm=tm, tn=tn, rp=rp, cp=cp,
        n_rows=n_rows, n_cols=n_cols)

    a, d = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs + pol_specs,
        out_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),  # A tile
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),   # degree (acc over j)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), out_dtype),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands, *pol_ops)
    return a[:n_rows, :n_cols], d[:n_rows, 0]
