"""pjit-able train step builder: loss, microbatched grad accumulation, AdamW.

``build_train_step(cfg, tcfg)`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for jax.jit with in/out shardings from distributed.sharding rules.
Microbatching splits the per-step batch into ``tcfg.microbatch`` slices and
accumulates grads with a lax.scan (keeps activation memory ∝ one microbatch).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, TrainConfig
from ..distributed.sharding import constrain
from ..models import get_api
from .compression import compress_decompress
from .optimizer import adamw_update


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def softmax_xent(logits, labels, z_loss=0.0):
    """Mean token cross-entropy (+ z-loss) in f32. logits (b,s,v)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def loss_fn(params, cfg: ModelConfig, batch, tcfg: TrainConfig):
    api = get_api(cfg)
    kw = dict(compute_dtype=_dtype(tcfg.compute_dtype), remat=tcfg.remat)
    if cfg.family == "moe":
        logits, aux = api.forward(params, cfg, batch, return_aux=True, **kw)
    else:
        logits, aux = api.forward(params, cfg, batch, **kw), 0.0
    loss = softmax_xent(logits, batch["labels"], tcfg.z_loss)
    return loss + aux, {"xent": loss, "aux": aux}


def _split_microbatches(batch, n):
    def f(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatch {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(f, batch)


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b, tcfg), has_aux=True)

    def train_step(params, opt_state, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            mb = _split_microbatches(batch, tcfg.microbatch)

            def acc_body(carry, mbatch):
                gsum, lsum = carry
                (l, _aux), g = grad_fn(params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, 0.0), mb)
            inv = 1.0 / tcfg.microbatch
            grads = jax.tree.map(lambda g: g * inv, gsum)
            loss = lsum * inv
        else:
            (loss, _aux), grads = grad_fn(params, batch)

        if tcfg.gradient_compression:
            grads, comp_err = compress_decompress(grads)
        params2, opt2, om = adamw_update(params, grads, opt_state, tcfg)
        metrics = {"loss": loss, **om}
        return params2, opt2, metrics

    return train_step


# ---------------------------------------------------------------------------
# serve steps (used by launch/serve.py and the dry-run decode cells)
# ---------------------------------------------------------------------------


def build_decode_step(cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    api = get_api(cfg)

    def serve_step(params, tokens, cache, pos, extras=None):
        logits, cache = api.decode_step(params, cfg, tokens, cache, pos,
                                        extras, compute_dtype=compute_dtype)
        # mask vocab-padding columns (embedding table is padded to 128)
        logits = logits[..., : cfg.vocab_size]
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def build_prefill(cfg: ModelConfig, max_len: int, compute_dtype=jnp.bfloat16):
    api = get_api(cfg)

    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch, max_len,
                           compute_dtype=compute_dtype)

    return prefill_step
