"""AdamW optimizer + LR schedule, dependency-free (no optax).

Moments are kept in f32 regardless of param dtype (bf16-safe). State is a
pytree mirroring params — shards with the same PartitionSpecs (ZeRO-free
baseline; a "dp_shard" variant reduce-scatters moments, see train_step).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig


@jax.tree_util.register_dataclass
@dataclass
class AdamWState:
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def lr_schedule(step, cfg: TrainConfig):
    """Linear warmup + cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state: AdamWState, cfg: TrainConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
        state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + 1e-8) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    new_state = AdamWState(step=step, mu=mu, nu=nu)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
