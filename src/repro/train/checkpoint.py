"""Sharded, async, elastic checkpointing (dependency-free; no orbax).

Layout: a checkpoint is a directory
    step_000123/
        manifest.json        tree structure, leaf dtypes/shapes, step, meta
        leaf_00000.npy ...   one file per pytree leaf (host-gathered)

Design notes for the 1000-node deployment (documented; single-host container
exercises the same code paths):
  - every host saves only its addressable shards; the manifest records the
    global shape + sharding so any *other* mesh can restore (elastic resize) —
    restore() takes an optional (mesh, specs) and device_puts with the new
    sharding, which is exactly the reshard path used when scaling up/down.
  - writes go to a tmp dir + atomic rename, so a failure mid-save never
    corrupts the latest checkpoint (crash consistency).
  - ``save_async`` runs serialization on a background thread; the train loop
    only blocks on the *previous* save (double-buffering).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.health import CheckpointCorruptError


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _treedef_to_str(treedef) -> str:
    return str(treedef)


def _leaf_crc(arr: np.ndarray) -> int:
    """CRC32 of a leaf's raw bytes (C-contiguous view) — the per-leaf
    integrity check recorded in the manifest and re-verified on restore."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save(path: str, tree: Any, *, step: int, extra: Optional[dict] = None):
    """Synchronous atomic checkpoint save."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": _treedef_to_str(treedef),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:          # numpy can't round-trip bf16
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append(
            {"dtype": logical_dtype, "shape": list(arr.shape),
             "crc32": _leaf_crc(arr)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


class AsyncCheckpointer:
    """Double-buffered async saver: wait for the previous save, then kick
    off the next on a daemon thread."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, path: str, tree: Any, *, step: int,
                   extra: Optional[dict] = None):
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO on worker
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host_leaves)

        def work():
            save(path, snapshot, step=step, extra=extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()


def restore(path: str, like: Any, *, mesh=None, specs=None):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs). If (mesh, specs) given, device_put each leaf with its
    NamedSharding — this is the elastic-reshard path (restore onto a mesh of
    any size/shape)."""
    from jax.sharding import NamedSharding

    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path}: unreadable manifest ({e})") from e
    leaves_like, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise CheckpointCorruptError(
            f"checkpoint {path}: has {manifest['n_leaves']} leaves, "
            f"expected {len(leaves_like)}")
    out = []
    spec_leaves = None
    if specs is not None:
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, tuple) or s is None)
    for i, ref in enumerate(leaves_like):
        entry = manifest["leaves"][i]
        try:
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        except (OSError, ValueError, EOFError) as e:
            raise CheckpointCorruptError(
                f"checkpoint {path}: leaf {i} missing or truncated "
                f"({e})") from e
        # crc32 absent in pre-PR-9 manifests — those restore unchecked
        if "crc32" in entry and _leaf_crc(arr) != entry["crc32"]:
            raise CheckpointCorruptError(
                f"checkpoint {path}: leaf {i} checksum mismatch "
                f"(stored crc32={entry['crc32']})")
        if entry["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(ref.shape):
            raise CheckpointCorruptError(
                f"checkpoint {path}: leaf {i} shape {arr.shape} != "
                f"expected {tuple(ref.shape)}")
        a = jnp.asarray(arr, dtype=ref.dtype)
        if mesh is not None and spec_leaves is not None:
            from ..distributed.sharding import logical_to_spec
            spec = spec_leaves[i]
            pspec = logical_to_spec(spec) if isinstance(spec, tuple) else None
            if pspec is not None:
                a = jax.device_put(a, NamedSharding(mesh, pspec))
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


def latest_step(root: str) -> Optional[str]:
    """Most recent step_* checkpoint dir under root (None if none)."""
    if not os.path.isdir(root):
        return None
    steps = sorted(d for d in os.listdir(root)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(root, steps[-1]) if steps else None


def manifest_extra(path: str) -> dict:
    """The ``extra`` dict a snapshot was saved with (raises
    :class:`CheckpointCorruptError` on an unreadable manifest)."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f).get("extra", {})
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path}: unreadable manifest ({e})") from e


def quarantine(path: str) -> str:
    """Rename a corrupt snapshot dir so ``latest_step`` skips it (keeping
    the bytes on disk for post-mortem). Returns the new path."""
    root, name = os.path.split(path)
    dst = os.path.join(root, "corrupt_" + name)   # no step_ prefix →
    if os.path.exists(dst):                       # latest_step skips it
        shutil.rmtree(dst)
    os.replace(path, dst)
    return dst


def restore_latest_valid(root: str, like: Any, *, mesh=None, specs=None):
    """Restore the newest snapshot under ``root`` that passes its integrity
    checks, quarantining corrupt ones and falling back to the previous
    valid step — the supervisor's resume entry point.

    Returns (tree, step, path, skipped): ``skipped`` lists the quarantined
    dirs (original names), newest first. (None, None, None, skipped) when
    no valid snapshot survives.
    """
    skipped: list[str] = []
    while True:
        path = latest_step(root)
        if path is None:
            return None, None, None, skipped
        try:
            tree, step = restore(path, like, mesh=mesh, specs=specs)
            return tree, step, path, skipped
        except CheckpointCorruptError:
            skipped.append(path)
            quarantine(path)
