"""Fault tolerance: restartable training, failure injection, stragglers.

Components:
  - ``RestartableLoop``: wraps a step fn with periodic (async) checkpointing;
    on any exception it restores the latest checkpoint and resumes. Training
    is bit-exact across a restart because the step fn is pure and the loop
    replays from the checkpointed (params, opt_state, step, data cursor).
  - ``FailureInjector``: raises SimulatedFailure at configured steps —
    used by tests and the train driver's --inject-failure flag.
  - ``StragglerMonitor``: online per-step timing stats; flags steps slower
    than ``threshold`` × running median (the multi-pod driver would use this
    to trigger hot-spare swaps / re-slicing; here it feeds metrics + logs).
  - ``inject_nan_features`` / ``ClusteringFaultHarness``: the clustering-
    side fault matrix (DESIGN.md §12) — corrupt inputs per trial through
    the same injector/monitor primitives and record whether each ``run_gpic``
    call succeeded clean, degraded with a populated health report, or
    raised a typed GPICError. Drives tests/test_robustness.py.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..core.health import GPICError, is_recovery_note
from . import checkpoint as ckpt


class SimulatedFailure(RuntimeError, GPICError):
    """An injected fault. Doubly based: RuntimeError for the historical
    train-loop handlers, GPICError so the run_gpic supervisor classifies
    an injected segment failure as retryable (resume from snapshot)."""


class FailureInjector:
    def __init__(self, fail_at_steps=(), exc=SimulatedFailure):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired: set = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected failure at step {step}")


@dataclass
class StragglerMonitor:
    threshold: float = 2.0
    window: int = 50
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = sorted(self.times)[len(self.times) // 2]
        is_straggler = len(self.times) >= 5 and seconds > self.threshold * med
        if is_straggler:
            self.flagged.append((step, seconds, med))
        return is_straggler

    @property
    def median(self) -> float:
        return sorted(self.times)[len(self.times) // 2] if self.times else 0.0


def inject_nan_features(x, rows, *, value: float = float("nan")):
    """Corrupt the given feature rows with ``value`` (NaN by default) —
    the non-finite-input fault class of the clustering fault matrix."""
    x = jnp.asarray(x)
    rows = jnp.asarray(rows, jnp.int32)
    return x.at[rows].set(jnp.asarray(value, x.dtype))


class ClusteringFaultHarness:
    """Run GPIC trials under injected faults and record what came back.

    Promotes the training-side primitives into the clustering path: a
    :class:`FailureInjector` decides which trials corrupt their input
    (reusing its fire-once step accounting), a :class:`StragglerMonitor`
    times every trial, and each outcome is classified by the robustness
    contract (DESIGN.md §12):

      'ok'          — clean result, no health notes, all columns COL_OK
      'recovered'   — clean arrays (all columns COL_OK, no isolated rows)
                      whose only notes are the supervisor's recovery
                      history (``resumed:``/``retry:``/``straggler:``/
                      fallback-resume — :func:`~repro.core.health.
                      is_recovery_note`): the run hit faults and the
                      resumable layer absorbed them without damage
      'degraded'    — result returned with damage described in
                      ``result.health`` (isolated rows, dead/stalled
                      columns, sanitization or kernel-fallback notes)
      'typed_error' — a GPICError subclass was raised (the contract's
                      failure half; anything else propagates — a harness
                      crash IS a robustness bug)

    ``corrupt_fn(x, trial) -> x`` applies the fault (e.g.
    :func:`inject_nan_features`) on trials where the injector fires.
    """

    def __init__(self, *, fail_at_trials=(), corrupt_fn: Callable = None,
                 straggler_threshold: float = 2.0):
        self.injector = FailureInjector(fail_at_steps=fail_at_trials)
        self.corrupt_fn = corrupt_fn or (
            lambda x, trial: inject_nan_features(x, [trial % x.shape[0]]))
        self.monitor = StragglerMonitor(threshold=straggler_threshold)
        self.outcomes: list = []

    def run_trial(self, trial: int, x, k: int, config=None, **kwargs):
        """One clustering attempt; returns the outcome record (also kept
        in ``self.outcomes``)."""
        from ..core import GPICError, run_gpic
        from ..core.health import COL_OK

        try:
            self.injector.maybe_fail(trial)
        except SimulatedFailure:
            x = self.corrupt_fn(x, trial)
        t0 = time.perf_counter()
        record: dict = {"trial": trial,
                        "injected": trial in self.injector.fired}
        try:
            res = run_gpic(x, k, config, **kwargs)
        except GPICError as e:
            record.update(status="typed_error", error=type(e).__name__,
                          message=str(e))
        else:
            h = res.health
            arrays_clean = h is None or (
                int(h.isolated_rows) == 0
                and bool((jax.device_get(h.col_status) == COL_OK).all()))
            notes = () if h is None else h.notes
            if arrays_clean and not notes:
                status = "ok"
            elif arrays_clean and all(is_recovery_note(n) for n in notes):
                status = "recovered"
            else:
                status = "degraded"
            record.update(status=status,
                          labels=jax.device_get(res.labels),
                          health=None if h is None else h.to_dict())
        record["sec"] = time.perf_counter() - t0
        self.monitor.record(trial, record["sec"])
        self.outcomes.append(record)
        return record

    def summary(self) -> dict:
        counts: dict = {}
        for r in self.outcomes:
            counts[r["status"]] = counts.get(r["status"], 0) + 1
        return {"trials": len(self.outcomes), "counts": counts,
                "stragglers": len(self.monitor.flagged)}


class RestartableLoop:
    """Checkpoint/restart training loop.

    step_fn: (state, batch) -> (state, metrics) — pure, jitted by caller.
    data_fn: (step:int) -> batch — deterministic per step (replayable).
    """

    def __init__(self, step_fn: Callable, data_fn: Callable, ckpt_dir: str,
                 *, ckpt_every: int = 50, max_restarts: int = 10,
                 injector: Optional[FailureInjector] = None,
                 async_save: bool = True):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.saver = ckpt.AsyncCheckpointer() if async_save else None
        self.monitor = StragglerMonitor()
        self.restarts = 0

    def _save(self, state, step):
        path = os.path.join(self.ckpt_dir, f"step_{step:06d}")
        if self.saver:
            self.saver.save_async(path, state, step=step)
        else:
            ckpt.save(path, state, step=step)

    def _restore(self, state_like):
        path = ckpt.latest_step(self.ckpt_dir)
        if path is None:
            return None
        state, step = ckpt.restore(path, state_like)
        return state, step

    def run(self, state, n_steps: int, *, start_step: int = 0):
        """Runs to n_steps, surviving injected/real failures."""
        step = start_step
        metrics_log = []
        # initial checkpoint so a pre-first-save failure restores cleanly
        self._save(state, step)
        if self.saver:
            self.saver.wait()
        while step < n_steps:
            try:
                while step < n_steps:
                    if self.injector:
                        self.injector.maybe_fail(step)
                    t0 = time.perf_counter()
                    batch = self.data_fn(step)
                    state, metrics = self.step_fn(state, batch)
                    dt = time.perf_counter() - t0
                    self.monitor.record(step, dt)
                    metrics_log.append(
                        {"step": step, "sec": dt,
                         **{k: float(v) for k, v in metrics.items()}})
                    step += 1
                    if step % self.ckpt_every == 0:
                        self._save(state, step)
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                restored = self._restore(state)
                if restored is None:
                    step = start_step  # no checkpoint yet — replay from start
                else:
                    state, step = restored
        if self.saver:
            self.saver.wait()
        self._save(state, step)
        if self.saver:
            self.saver.wait()
        return state, step, metrics_log


@dataclass(frozen=True)
class FaultSchedule:
    """A CONCURRENT multi-fault recipe — every listed fault is live in the
    SAME run (the beyond-single-fault matrix, DESIGN.md §14):

      nan_rows:        feature rows replaced with NaN (front-door class —
                       raises NonFiniteInputError unless cfg.sanitize)
      isolate_rows:    feature rows moved to a far outlier at
                       ``outlier_distance``, so an rbf affinity underflows
                       their whole row to exact zero degree (device-side
                       isolated-row latch)
      ring_stage:      poison this sharded streaming ring stage's consumed
                       block with NaN (cfg must route mesh+streaming)
      kernel_failure:  force this Pallas op's dispatch to raise, exercising
                       the guarded reference fallback mid-run
      fail_sweeps:     sweep counts at which the supervisor's segment
                       injector raises SimulatedFailure (fire-once each —
                       the resume-from-snapshot path)
    """
    nan_rows: tuple = ()
    isolate_rows: tuple = ()
    ring_stage: Optional[int] = None
    kernel_failure: Optional[str] = None
    fail_sweeps: tuple = ()
    outlier_distance: float = 60.0


def apply_feature_faults(x, schedule: FaultSchedule):
    """Corrupt the feature matrix per the schedule's input-fault classes
    (NaN rows, isolated-outlier rows); engine/supervisor faults are wired
    by :func:`run_schedule`."""
    x = jnp.asarray(x)
    if schedule.nan_rows:
        x = inject_nan_features(x, list(schedule.nan_rows))
    if schedule.isolate_rows:
        rows = jnp.asarray(schedule.isolate_rows, jnp.int32)
        x = x.at[rows].set(jnp.asarray(schedule.outlier_distance, x.dtype))
    return x


def run_schedule(x, k: int, schedule: FaultSchedule, config=None, **kwargs):
    """One supervised GPIC run with every fault in ``schedule`` live at
    once, classified by the robustness contract ('ok' / 'recovered' /
    'degraded' / 'typed_error' — never an unclassified crash). Returns the
    outcome record; ``record['notes']`` carries the supervisor's
    retry/resume history."""
    import contextlib

    from ..core import GPICError, run_gpic
    from ..core.health import COL_OK
    from ..kernels import ops

    x = apply_feature_faults(x, schedule)
    cfg = config
    if schedule.ring_stage is not None:
        cfg = cfg.with_(
            inject_ring_fault=("ring_nan", schedule.ring_stage))
    injector = (FailureInjector(fail_at_steps=schedule.fail_sweeps)
                if schedule.fail_sweeps else None)
    cm = (ops.forced_kernel_failure(schedule.kernel_failure)
          if schedule.kernel_failure else contextlib.nullcontext())
    record: dict = {"faults": {
        "nan_rows": list(schedule.nan_rows),
        "isolate_rows": list(schedule.isolate_rows),
        "ring_stage": schedule.ring_stage,
        "kernel_failure": schedule.kernel_failure,
        "fail_sweeps": list(schedule.fail_sweeps)}}
    if schedule.kernel_failure:
        jax.clear_caches()       # dispatch is trace-time: drop cached paths
    try:
        with cm:
            res = run_gpic(
                x, k, cfg,
                segment_injector=(None if injector is None
                                  else injector.maybe_fail),
                **kwargs)
    except GPICError as e:
        record.update(status="typed_error", error=type(e).__name__,
                      message=str(e))
    else:
        h = res.health
        arrays_clean = h is None or (
            int(h.isolated_rows) == 0
            and bool((jax.device_get(h.col_status) == COL_OK).all()))
        notes = () if h is None else h.notes
        if arrays_clean and not notes:
            status = "ok"
        elif arrays_clean and all(is_recovery_note(n) for n in notes):
            status = "recovered"
        else:
            status = "degraded"
        record.update(status=status, labels=jax.device_get(res.labels),
                      notes=list(notes),
                      health=None if h is None else h.to_dict())
    finally:
        if schedule.kernel_failure:
            jax.clear_caches()   # recovery is also trace-time
    return record
