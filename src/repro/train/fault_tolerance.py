"""Fault tolerance: restartable training, failure injection, stragglers.

Components:
  - ``RestartableLoop``: wraps a step fn with periodic (async) checkpointing;
    on any exception it restores the latest checkpoint and resumes. Training
    is bit-exact across a restart because the step fn is pure and the loop
    replays from the checkpointed (params, opt_state, step, data cursor).
  - ``FailureInjector``: raises SimulatedFailure at configured steps —
    used by tests and the train driver's --inject-failure flag.
  - ``StragglerMonitor``: online per-step timing stats; flags steps slower
    than ``threshold`` × running median (the multi-pod driver would use this
    to trigger hot-spare swaps / re-slicing; here it feeds metrics + logs).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from . import checkpoint as ckpt


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_steps=(), exc=SimulatedFailure):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired: set = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected failure at step {step}")


@dataclass
class StragglerMonitor:
    threshold: float = 2.0
    window: int = 50
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = sorted(self.times)[len(self.times) // 2]
        is_straggler = len(self.times) >= 5 and seconds > self.threshold * med
        if is_straggler:
            self.flagged.append((step, seconds, med))
        return is_straggler

    @property
    def median(self) -> float:
        return sorted(self.times)[len(self.times) // 2] if self.times else 0.0


class RestartableLoop:
    """Checkpoint/restart training loop.

    step_fn: (state, batch) -> (state, metrics) — pure, jitted by caller.
    data_fn: (step:int) -> batch — deterministic per step (replayable).
    """

    def __init__(self, step_fn: Callable, data_fn: Callable, ckpt_dir: str,
                 *, ckpt_every: int = 50, max_restarts: int = 10,
                 injector: Optional[FailureInjector] = None,
                 async_save: bool = True):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector
        self.saver = ckpt.AsyncCheckpointer() if async_save else None
        self.monitor = StragglerMonitor()
        self.restarts = 0

    def _save(self, state, step):
        path = os.path.join(self.ckpt_dir, f"step_{step:06d}")
        if self.saver:
            self.saver.save_async(path, state, step=step)
        else:
            ckpt.save(path, state, step=step)

    def _restore(self, state_like):
        path = ckpt.latest_step(self.ckpt_dir)
        if path is None:
            return None
        state, step = ckpt.restore(path, state_like)
        return state, step

    def run(self, state, n_steps: int, *, start_step: int = 0):
        """Runs to n_steps, surviving injected/real failures."""
        step = start_step
        metrics_log = []
        # initial checkpoint so a pre-first-save failure restores cleanly
        self._save(state, step)
        if self.saver:
            self.saver.wait()
        while step < n_steps:
            try:
                while step < n_steps:
                    if self.injector:
                        self.injector.maybe_fail(step)
                    t0 = time.perf_counter()
                    batch = self.data_fn(step)
                    state, metrics = self.step_fn(state, batch)
                    dt = time.perf_counter() - t0
                    self.monitor.record(step, dt)
                    metrics_log.append(
                        {"step": step, "sec": dt,
                         **{k: float(v) for k, v in metrics.items()}})
                    step += 1
                    if step % self.ckpt_every == 0:
                        self._save(state, step)
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                restored = self._restore(state)
                if restored is None:
                    step = start_step  # no checkpoint yet — replay from start
                else:
                    state, step = restored
        if self.saver:
            self.saver.wait()
        self._save(state, step)
        if self.saver:
            self.saver.wait()
        return state, step, metrics_log
