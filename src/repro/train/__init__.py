from .optimizer import AdamWState, adamw_init, adamw_update, lr_schedule
from .train_step import build_train_step, loss_fn

__all__ = [
    "adamw_init", "adamw_update", "AdamWState", "lr_schedule",
    "build_train_step", "loss_fn",
]
