"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-row quantization applied to gradients before the data-parallel
reduction. On a real multi-pod deployment the int8 representation is what
crosses the DCN (pod) axis — here we provide:

  - quantize/dequantize kernels (row-wise scale, stochastic-rounding option)
  - ``compress_decompress``: the in-graph q->dq round-trip used by the train
    step (XLA reduces the dequantized values; the *information loss* is the
    same as a real int8 all-reduce, so convergence behaviour is faithful)
  - ``ErrorFeedback``: residual accumulator (Seide et al. / EF-SGD) so the
    quantization error is re-injected next step — keeps SGD/Adam convergence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Row-wise (last-dim) symmetric int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads):
    """Round-trip every gradient leaf through int8. Returns (grads, err)."""
    def roundtrip(g):
        if g.ndim == 0:
            return g, jnp.zeros_like(g)
        q, s = quantize_int8(g)
        dq = dequantize_int8(q, s).astype(g.dtype)
        return dq, g - dq

    pairs = jax.tree.map(roundtrip, grads)
    dq = jax.tree.map(lambda p: p[0], pairs,
                      is_leaf=lambda p: isinstance(p, tuple))
    err = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda p: isinstance(p, tuple))
    return dq, err


@jax.tree_util.register_dataclass
@dataclass
class ErrorFeedback:
    residual: Any

    @staticmethod
    def init(params):
        return ErrorFeedback(
            residual=jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress(grads, ef: ErrorFeedback):
    """Error-feedback compression: q(g + r); r' = (g + r) - dq."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, ef.residual)
    dq, err = compress_decompress(corrected)
    return dq, ErrorFeedback(residual=err)
