"""mamba2-780m [ssm]: 48L d_model=1536 attention-free, ssm_state=128 — SSD
(state-space duality) [arXiv:2405.21060; unverified]. Runs long_500k."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    subquadratic=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, vocab_size=384,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16))
