"""zamba2-2.7b [hybrid]: 54 Mamba2 blocks d_model=2560 ssm_state=64 + shared
attention block (32H) applied every 6 SSM blocks [arXiv:2411.15242; hf].
Sub-quadratic (SSM backbone) -> runs long_500k."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    shared_attn_every=6, subquadratic=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=384,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        shared_attn_every=2)
