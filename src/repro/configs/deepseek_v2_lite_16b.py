"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (kv=16) vocab=102400 —
MLA kv_lora=512, first layer dense (d_ff=10944), 26 MoE layers with 2 shared
+ 64 routed experts (d_ff_expert=1408) top-6 [arXiv:2405.04434; hf].

Note: the assignment header lists "MoE 64e top-6" and the note "160 routed"
(the 236B V2's count); we implement the Lite variant: 64 routed experts.
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400, head_dim=128,
    moe=MoEConfig(n_experts=64, n_shared_experts=2, top_k=6,
                  d_ff_expert=1408, moe_every=1, first_dense=1),
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=96, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=256, vocab_size=512,
        moe=MoEConfig(n_experts=8, n_shared_experts=1, top_k=2,
                      d_ff_expert=64, moe_every=1, first_dense=1,
                      capacity_factor=8.0),  # no drops at smoke scale
        mla=MLAConfig(kv_lora_rank=32, rope_head_dim=16, nope_head_dim=24,
                      v_head_dim=24))
