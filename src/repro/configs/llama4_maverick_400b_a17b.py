"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048 — 128 routed experts top-1 + 1 shared expert,
MoE every other layer (interleaved), early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E lineage; unverified]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    moe=MoEConfig(n_experts=128, n_shared_experts=1, top_k=1,
                  d_ff_expert=8192, moe_every=2, first_dense=0),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=96, n_heads=4, n_kv_heads=2, head_dim=24,
        d_ff=256, vocab_size=512,
        moe=MoEConfig(n_experts=8, n_shared_experts=1, top_k=1,
                      d_ff_expert=256, moe_every=2, first_dense=0,
                      capacity_factor=8.0))  # no drops at smoke scale
