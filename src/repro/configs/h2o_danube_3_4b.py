"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention (window 4096)
[arXiv:2401.16818; unverified]. SWA makes it sub-quadratic -> runs long_500k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    sliding_window=4096, subquadratic=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=96, n_heads=4, n_kv_heads=2,
                          head_dim=24, d_ff=256, vocab_size=384,
                          sliding_window=16)
