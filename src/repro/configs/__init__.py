"""Architecture config registry: get_config(arch_id) / get_smoke_config."""
from __future__ import annotations

import importlib

from .base import (
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SHAPE_CELLS,
    ShapeCell,
    SSMConfig,
    TrainConfig,
)

ARCH_IDS = (
    "granite-34b",
    "stablelm-3b",
    "h2o-danube-3-4b",
    "qwen1.5-4b",
    "seamless-m4t-large-v2",
    "paligemma-3b",
    "zamba2-2.7b",
    "mamba2-780m",
    "deepseek-v2-lite-16b",
    "llama4-maverick-400b-a17b",
)

_MODULES = {
    "granite-34b": "granite_34b",
    "stablelm-3b": "stablelm_3b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen1.5-4b": "qwen1_5_4b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "paligemma-3b": "paligemma_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-780m": "mamba2_780m",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch_id]}", __package__)


def get_config(arch_id: str) -> ModelConfig:
    """The exact published configuration."""
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for one-step CPU smoke tests."""
    return _module(arch_id).smoke()


__all__ = [
    "ARCH_IDS", "get_config", "get_smoke_config",
    "ModelConfig", "MoEConfig", "SSMConfig", "MLAConfig",
    "TrainConfig", "ShapeCell", "SHAPE_CELLS",
]
