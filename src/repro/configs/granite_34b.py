"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1/MQA) d_ff=24576
vocab=49152 — llama-arch code model, non-gated MLP (GPTBigCode lineage)
[arXiv:2405.04324; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    notes="mlp_nogate",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=128, n_heads=4, n_kv_heads=1,
                          head_dim=32, d_ff=512, vocab_size=512)
