"""seamless-m4t-large-v2 [audio enc-dec]: 24L d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206 [arXiv:2308.11596; hf].

Backbone only: the audio frontend is a stub (precomputed frame embeddings).
24 encoder + 24 decoder layers, non-gated transformer FFN (fairseq lineage).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, head_dim=64,
    notes="mlp_nogate",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, n_enc_layers=2, d_model=96, n_heads=4,
                          n_kv_heads=4, head_dim=24, d_ff=256, vocab_size=512)
