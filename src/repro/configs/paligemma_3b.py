"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1/MQA, head_dim 256)
d_ff=16384 vocab=257216 — SigLIP frontend STUBBED as 256 precomputed patch
embeddings; gemma-style decoder with prefix-LM masking [arXiv:2407.07726; hf].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=257216, head_dim=256,
    n_prefix_tokens=256, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=96, n_heads=4, n_kv_heads=1,
                          head_dim=24, d_ff=256, vocab_size=512,
                          n_prefix_tokens=16)
