"""stablelm-3b [dense]: 32L d_model=2560 32H (MHA kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b lineage; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304, head_dim=80,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=96, n_heads=4, n_kv_heads=4,
                          head_dim=24, d_ff=256, vocab_size=384)
