"""Config dataclasses for the model zoo, training, and meshes.

Every assigned architecture gets a ``ModelConfig`` in ``configs/<id>.py`` with
the exact published dimensions, plus a ``reduced()`` variant for CPU smoke
tests (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence

Family = Literal["dense", "encdec", "vlm", "hybrid", "ssm", "moe"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    n_shared_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    moe_every: int = 1            # MoE layer every N layers (1 = all layers)
    first_dense: int = 0          # leading dense layers (deepseek-v2 style)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # SSD head size P
    chunk: int = 128              # SSD chunk length (MXU-friendly)
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    q_lora_rank: int = 0          # 0 = full-rank q projection


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    sliding_window: int = 0       # 0 = full attention
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # hybrid (zamba2-style): shared attention block every N ssm blocks
    shared_attn_every: int = 0
    # enc-dec
    n_enc_layers: int = 0         # when family == "encdec", n_layers = decoder
    # vlm / audio stub frontends: number of prefix embedding positions
    n_prefix_tokens: int = 0
    # which attention layout the arch supports for >= 500k decode
    subquadratic: bool = False
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded to a multiple of 128 so the vocab
        dim shards over any mesh axis (MaxText-style). Logits are produced
        at the padded size; labels always index < vocab_size."""
        return ((self.vocab_size + 127) // 128) * 128

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell for the dry-run grid."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPE_CELLS: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 4096
    global_batch: int = 256
    microbatch: int = 0           # 0 = no microbatching (single shot)
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    param_dtype: str = "float32"  # smoke tests use f32; prod bf16+f32 master
    compute_dtype: str = "bfloat16"
    remat: Literal["none", "dots", "full"] = "full"
    z_loss: float = 1e-4
    gradient_compression: bool = False
    seed: int = 0
