"""qwen1.5-4b [dense]: 40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936 —
QKV bias enabled [hf:Qwen/Qwen1.5-0.5B lineage; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab_size=151936, head_dim=128,
    qkv_bias=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=96, n_heads=4, n_kv_heads=4,
                          head_dim=24, d_ff=256, vocab_size=512)
