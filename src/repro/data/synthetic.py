"""Synthetic datasets used by the paper.

Experiment I (speed): ``two_moons``, ``three_circles``  (m = 2).
Experiment II (subsampling quality): ``cassini``, ``gaussians``, ``shapes``,
``smiley`` — mlbench-style 2-D generators with ground-truth labels.

All generators return ``(X float32 (n, 2), y int32 (n,))`` and are
deterministic given ``seed``. Class balance is as equal as n allows
(Experiment II requires balanced classes).

Ordering contract: every generator emits points CLASS-BY-CLASS (labels
are sorted), because downstream code must not depend on row order — PIC
itself is permutation-equivariant (property-tested), and any sampling
heuristic has to survive cluster-sorted input (the
``rbf_bandwidth_heuristic`` leading-slice bias fixed in PR 5 was exactly
such a dependency). Use :func:`shuffle_points` when a test needs the
order-randomized view of the same dataset.
"""
from __future__ import annotations

import numpy as np


def shuffle_points(x: np.ndarray, y: np.ndarray, *, seed: int = 0):
    """Deterministic row shuffle of a (X, y) dataset — the antidote to the
    generators' class-sorted ordering contract (see module doc)."""
    perm = np.random.default_rng(seed).permutation(len(y))
    return x[perm], y[perm]


def _split_counts(n: int, k: int) -> list[int]:
    base = n // k
    counts = [base] * k
    for i in range(n - base * k):
        counts[i] += 1
    return counts


def two_moons(n: int, *, noise: float = 0.06, seed: int = 0):
    rng = np.random.default_rng(seed)
    n0, n1 = _split_counts(n, 2)
    t0 = rng.uniform(0.0, np.pi, n0)
    t1 = rng.uniform(0.0, np.pi, n1)
    upper = np.stack([np.cos(t0), np.sin(t0)], axis=1)
    lower = np.stack([1.0 - np.cos(t1), 0.5 - np.sin(t1)], axis=1)
    x = np.concatenate([upper, lower], axis=0)
    x += rng.normal(0.0, noise, x.shape)
    y = np.concatenate([np.zeros(n0, np.int32), np.ones(n1, np.int32)])
    return x.astype(np.float32), y


def three_circles(n: int, *, noise: float = 0.04, seed: int = 0):
    rng = np.random.default_rng(seed)
    counts = _split_counts(n, 3)
    radii = (1.0, 2.2, 3.4)
    xs, ys = [], []
    for cls, (cnt, r) in enumerate(zip(counts, radii)):
        t = rng.uniform(0.0, 2.0 * np.pi, cnt)
        pts = r * np.stack([np.cos(t), np.sin(t)], axis=1)
        pts += rng.normal(0.0, noise, pts.shape)
        xs.append(pts)
        ys.append(np.full(cnt, cls, np.int32))
    return np.concatenate(xs).astype(np.float32), np.concatenate(ys)


def cassini(n: int, *, seed: int = 0):
    """mlbench-cassini style: two banana-shaped lobes around a central disc."""
    rng = np.random.default_rng(seed)
    counts = _split_counts(n, 3)
    xs, ys = [], []
    # two banana-shaped annular arcs (classes 0, 1), above and below the disc
    for cls, sign in ((0, 1.0), (1, -1.0)):
        cnt = counts[cls]
        t = rng.uniform(0.2 * np.pi, 0.8 * np.pi, cnt)  # arc does not wrap
        r = rng.uniform(1.6, 2.4, cnt)
        pts = np.stack([r * np.cos(t), sign * r * np.sin(t)], axis=1)
        xs.append(pts)
        ys.append(np.full(cnt, cls, np.int32))
    # central disc (class 2)
    cnt = counts[2]
    t = rng.uniform(0, 2 * np.pi, cnt)
    r = 0.45 * np.sqrt(rng.uniform(0, 1, cnt))
    xs.append(np.stack([r * np.cos(t), r * np.sin(t)], axis=1))
    ys.append(np.full(cnt, 2, np.int32))
    return np.concatenate(xs).astype(np.float32), np.concatenate(ys)


def anisotropic(n: int, *, seed: int = 0):
    """Three stretched (sheared) Gaussian blobs — the classic k-means
    failure case: isotropic distance misassigns the elongated tails, while
    affinity-graph methods follow the stretch. Used by the
    embedding-quality regression suite (tests/test_embedding_quality.py)."""
    rng = np.random.default_rng(seed)
    counts = _split_counts(n, 3)
    shear = np.array([[0.6, -0.6], [-0.4, 0.8]])
    centers = [(-2.5, 1.5), (0.0, -1.0), (2.5, 2.0)]
    xs, ys = [], []
    for cls, (cnt, center) in enumerate(zip(counts, centers)):
        pts = rng.normal(0.0, 0.45, (cnt, 2)) @ shear + np.array(center)
        xs.append(pts)
        ys.append(np.full(cnt, cls, np.int32))
    return np.concatenate(xs).astype(np.float32), np.concatenate(ys)


def gaussians(n: int, *, k: int = 4, spread: float = 0.35, seed: int = 0):
    """k well-separated isotropic Gaussian blobs on a circle."""
    rng = np.random.default_rng(seed)
    counts = _split_counts(n, k)
    xs, ys = [], []
    for cls, cnt in enumerate(counts):
        ang = 2.0 * np.pi * cls / k
        center = 3.0 * np.array([np.cos(ang), np.sin(ang)])
        xs.append(center + rng.normal(0.0, spread, (cnt, 2)))
        ys.append(np.full(cnt, cls, np.int32))
    return np.concatenate(xs).astype(np.float32), np.concatenate(ys)


def shapes(n: int, *, seed: int = 0):
    """mlbench-shapes style: gaussian blob, square, triangle and ring."""
    rng = np.random.default_rng(seed)
    counts = _split_counts(n, 4)
    xs, ys = [], []
    # 0: gaussian blob
    xs.append(np.array([-3.0, 3.0]) + rng.normal(0, 0.3, (counts[0], 2)))
    # 1: uniform square
    xs.append(np.array([3.0, 3.0]) + rng.uniform(-0.7, 0.7, (counts[1], 2)))
    # 2: triangle (uniform via sqrt trick)
    u = rng.uniform(0, 1, counts[2])
    v = rng.uniform(0, 1, counts[2])
    su = np.sqrt(u)
    a, b, c = np.array([-0.9, -0.8]), np.array([0.9, -0.8]), np.array([0.0, 0.8])
    tri = (1 - su)[:, None] * a + (su * (1 - v))[:, None] * b + (su * v)[:, None] * c
    xs.append(np.array([-3.0, -3.0]) + tri)
    # 3: ring
    t = rng.uniform(0, 2 * np.pi, counts[3])
    r = rng.normal(0.8, 0.05, counts[3])
    xs.append(np.array([3.0, -3.0]) + np.stack([r * np.cos(t), r * np.sin(t)], axis=1))
    ys = [np.full(c, i, np.int32) for i, c in enumerate(counts)]
    return np.concatenate(xs).astype(np.float32), np.concatenate(ys)


def smiley(n: int, *, seed: int = 0):
    """mlbench-smiley style: two eyes, a nose and a mouth arc (4 classes)."""
    rng = np.random.default_rng(seed)
    counts = _split_counts(n, 4)
    xs = []
    # 0, 1: eyes (gaussian blobs)
    xs.append(np.array([-0.8, 1.0]) + rng.normal(0, 0.15, (counts[0], 2)))
    xs.append(np.array([0.8, 1.0]) + rng.normal(0, 0.15, (counts[1], 2)))
    # 2: nose (triangle-ish vertical wedge)
    yy = rng.uniform(-0.4, 0.4, counts[2])
    half_w = 0.12 * (0.4 - yy) / 0.8 + 0.02
    xx = rng.uniform(-1.0, 1.0, counts[2]) * half_w
    xs.append(np.stack([xx, yy], axis=1))
    # 3: mouth (arc)
    t = rng.uniform(np.pi * 1.15, np.pi * 1.85, counts[3])
    r = rng.normal(1.3, 0.04, counts[3])
    xs.append(np.stack([r * np.cos(t), 0.3 + r * np.sin(t)], axis=1))
    ys = [np.full(c, i, np.int32) for i, c in enumerate(counts)]
    return np.concatenate(xs).astype(np.float32), np.concatenate(ys)


_REGISTRY = {
    "two_moons": (two_moons, 2),
    "three_circles": (three_circles, 3),
    "anisotropic": (anisotropic, 3),
    "cassini": (cassini, 3),
    "gaussians": (gaussians, 4),
    "shapes": (shapes, 4),
    "smiley": (smiley, 4),
}


def dataset_by_name(name: str, n: int, *, seed: int = 0):
    """Returns (X, y, k) for a registered dataset."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(_REGISTRY)}")
    fn, k = _REGISTRY[name]
    x, y = fn(n, seed=seed)
    return x, y, k


def subsample_balanced(x, y, fraction: float, *, seed: int = 0):
    """Balanced subsample used by Experiment II (equal per-class draws)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    take_total = max(int(round(len(y) * fraction)), len(classes))
    per_class = max(take_total // len(classes), 1)
    idx = []
    for c in classes:
        members = np.flatnonzero(y == c)
        idx.append(rng.choice(members, size=min(per_class, len(members)),
                              replace=False))
    idx = np.concatenate(idx)
    rng.shuffle(idx)
    return x[idx], y[idx]
