from .synthetic import (
    anisotropic,
    cassini,
    dataset_by_name,
    gaussians,
    shapes,
    shuffle_points,
    smiley,
    three_circles,
    two_moons,
)

__all__ = [
    "two_moons",
    "three_circles",
    "anisotropic",
    "cassini",
    "gaussians",
    "shapes",
    "smiley",
    "dataset_by_name",
    "shuffle_points",
]
