"""Synthetic LM token pipeline: deterministic, seekable, sharded.

A Zipf-distributed Markov stream gives the loss curve realistic structure
(learnable bigram statistics) without external data. ``batch_at(step)`` is a
pure function of (seed, step) so a restarted/rescaled job replays the exact
same data order — the property the fault-tolerance layer relies on.
"""
from __future__ import annotations

import numpy as np


class SyntheticTokenStream:
    def __init__(self, vocab_size: int, *, seed: int = 0, zipf_a: float = 1.2,
                 n_states: int = 64):
        self.vocab = vocab_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        # hidden-state bigram model: each state emits a zipf slice and moves
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        base = ranks ** (-zipf_a)
        self.n_states = n_states
        self.emit = np.stack([
            np.roll(base, rng.integers(0, vocab_size)) for _ in range(n_states)
        ])
        self.emit /= self.emit.sum(axis=1, keepdims=True)
        self.trans = rng.dirichlet(np.ones(n_states) * 0.5, size=n_states)

    def batch_at(self, step: int, batch: int, seq: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        state = rng.integers(0, self.n_states, size=batch)
        toks = np.empty((batch, seq + 1), np.int32)
        for t in range(seq + 1):
            u = rng.random(batch)
            # per-row categorical draws via cdf inverse on the emit rows
            cdf = np.cumsum(self.emit[state], axis=1)
            toks[:, t] = (u[:, None] < cdf).argmax(axis=1)
            state = np.array([
                rng.choice(self.n_states, p=self.trans[s]) for s in state])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
