"""Trip-count-aware HLO cost analysis (the roofline engine).

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE —
useless for scan-over-layers/microbatch models (measured: a 10-step scan of
matmuls reports the flops of one matmul). This module parses the
post-optimization HLO text and computes

    flops             2·M·N·K for dots (+1/elem for fused arithmetic)
    hbm bytes         operand+result bytes of non-fused instructions
    collective bytes  operand bytes of all-gather/all-reduce/reduce-scatter/
                      all-to-all/collective-permute

with every while body multiplied by its ``known_trip_count`` backend config
(nested loops compose multiplicatively). Loops with unknown trip count
multiply by 1 — i.e. per-iteration cost (the natural unit for convergence
loops like GPIC's power iteration).

Conventions follow HloCostAnalysis closely enough for roofline purposes:
fusions count only their boundary IO for bytes but their full interior for
flops; parameters/tuples/GTEs/bitcasts are free.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|"
    r"u4|pred)\[([0-9,]*)\]")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.+?\)?)\s+([\w\-]+)\((.*)$")

_CALLED_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_REF_RE = re.compile(r"%([\w.\-]+)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "negate", "abs", "rsqrt", "sqrt", "select",
    "compare", "and", "or", "xor", "not", "floor", "ceil", "sign",
    "cosine", "sine", "atan2", "remainder", "clamp", "expm1", "log1p",
    "logistic", "round-nearest-afz", "round-nearest-even", "erf",
}

# dtype converts are free: on TPU they fuse into producers/consumers (bf16
# dots are MXU-native); the CPU backend materializes f32 copies around every
# bf16 dot, which would systematically distort the memory roofline term.

FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
    "rng-get-and-update-state", "opt-barrier", "rng-bit-generator",
    "convert",
}

COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"}
_COLLECTIVE_OPCODES = COLLECTIVES | {c + "-start" for c in COLLECTIVES}

MOVEMENT_OPS = {
    "slice", "dynamic-slice", "dynamic-update-slice", "pad", "concatenate",
    "gather", "scatter", "transpose", "reshape", "broadcast", "reverse",
    "copy", "copy-start", "copy-done", "reduce-window", "sort", "custom-call",
    "select-and-scatter", "clz", "popcnt",
}


def _bytes_of_shapes(text: str) -> int:
    return sum(
        _DTYPE_BYTES[d] * (math.prod(int(x) for x in dims.split(",")) if dims
                           else 1)
        for d, dims in _SHAPE_RE.findall(text))


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    d, dims = m.groups()
    return d, ([int(x) for x in dims.split(",")] if dims else [])


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_per_op: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_per_op.items():
            self.collective_per_op[k] = self.collective_per_op.get(k, 0) + v
        for k, v in o.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.collective_bytes * m,
                    {k: v * m for k, v in self.collective_per_op.items()},
                    {k: v * m for k, v in self.collective_counts.items()})


@dataclass
class _Instr:
    name: str
    result_text: str
    opcode: str
    args_text: str
    is_root: bool = False


def _split_computations(text: str):
    """name -> (list of _Instr, symbol table name -> result_text)."""
    comps: dict[str, list[_Instr]] = {}
    cur = None
    for line in text.splitlines():
        clean = re.sub(r"/\*.*?\*/", "", line)   # strip /*index=N*/ comments
        header = re.match(
            r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$", clean)
        if header and " = " not in clean.split("->")[0]:
            cur = header.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, result_text, opcode, args_text = mi.groups()
            comps[cur].append(_Instr(name, result_text, opcode, args_text,
                                     is_root=line.lstrip().startswith("ROOT")))
    return comps


def _operand_args(args_text: str) -> str:
    """The operand list — everything up to the matching close paren."""
    depth = 1
    for i, ch in enumerate(args_text):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return args_text[:i]
    return args_text


def analyze(text: str, *, entry: str | None = None) -> Cost:
    comps = _split_computations(text)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        entry = m.group(1) if m else next(iter(comps))

    symtab: dict[str, dict[str, str]] = {
        cname: {i.name: i.result_text for i in instrs}
        for cname, instrs in comps.items()
    }
    memo: dict[str, Cost] = {}
    fusion_input_memo: dict[str, float] = {}

    SLICE_OPS = {"slice", "dynamic-slice", "gather", "get-tuple-element",
                 "bitcast", "reshape", "broadcast", "convert", "copy",
                 "transpose"}

    _PURE_CONVERT_OPS = {"parameter", "convert", "bitcast", "tuple",
                         "get-tuple-element"}
    pure_convert_memo: dict[str, bool] = {}

    def is_pure_convert_fusion(fname: str) -> bool:
        """wrapped_convert-style fusions (dtype cast only) are free — the
        CPU backend materializes f32 copies around bf16 dots that TPU's MXU
        consumes natively."""
        if fname not in pure_convert_memo:
            instrs = comps.get(fname, [])
            pure_convert_memo[fname] = bool(instrs) and all(
                i.opcode in _PURE_CONVERT_OPS for i in instrs)
        return pure_convert_memo[fname]

    def fusion_output_bytes(fname: str, result_text: str) -> float:
        """Fusions rooted in dynamic-update-slice write only the update
        region in place (the scan's per-layer cache/grad-accumulator write),
        not the whole loop-carried buffer."""
        instrs = comps.get(fname, [])
        root = next((i for i in instrs if i.is_root), instrs[-1] if instrs
                    else None)
        if root is not None and root.opcode == "dynamic-update-slice":
            table = {i.name: i.result_text for i in instrs}
            refs = _REF_RE.findall(_operand_args(root.args_text))
            if len(refs) >= 2 and refs[1] in table:
                return _bytes_of_shapes(table[refs[1]])
        return _bytes_of_shapes(result_text)

    def fusion_input_bytes(fname: str) -> float:
        """Effective bytes READ by a fused computation's parameters.

        HloCostAnalysis convention: a parameter that is only consumed by
        slice-like ops inside the fusion is charged at the sliced size, not
        the full (possibly 88-layer-stacked) operand size.
        """
        if fname in fusion_input_memo:
            return fusion_input_memo[fname]
        instrs = comps.get(fname, [])
        total = 0.0
        for p in instrs:
            if p.opcode != "parameter":
                continue
            def users_of(name):
                return [u for u in instrs
                        if u.name != name
                        and re.search(r"%" + re.escape(name) + r"\b",
                                      u.args_text)]

            def read_bytes(name, depth=0):
                """Effective read of a value consumed inside the fusion."""
                if depth > 4:
                    return None
                reads = []
                for u in users_of(name):
                    if u.opcode in ("slice", "dynamic-slice", "gather"):
                        reads.append(_bytes_of_shapes(u.result_text))
                    elif u.opcode == "dynamic-update-slice":
                        refs = _REF_RE.findall(_operand_args(u.args_text))
                        if refs and refs[0] == name:
                            reads.append(0.0)   # in-place buffer: aliased
                        else:
                            return None
                    elif u.opcode in ("convert", "bitcast", "copy"):
                        sub = read_bytes(u.name, depth + 1)
                        if sub is None:
                            return None
                        reads.append(sub)
                    else:
                        return None
                return sum(reads) if reads else None

            rb = read_bytes(p.name)
            total += (rb if rb is not None
                      else _bytes_of_shapes(p.result_text))
        fusion_input_memo[fname] = total
        return total

    def operand_bytes(cname: str, operands: str) -> int:
        total = _bytes_of_shapes(operands)   # inline-typed operands
        if total:
            return total
        table = symtab[cname]
        for ref in _REF_RE.findall(operands):
            if ref in table:
                total += _bytes_of_shapes(table[ref])
        return total

    def first_operand_shape(cname: str, operands: str):
        inline = _first_shape(operands)
        refs = _REF_RE.findall(operands)
        if inline and not operands.lstrip().startswith("%"):
            return inline
        if refs and refs[0] in symtab[cname]:
            return _first_shape(symtab[cname][refs[0]])
        return inline

    def comp_cost(cname: str) -> Cost:
        if cname in memo:
            return memo[cname]
        memo[cname] = Cost()  # cycle guard
        total = Cost()
        for ins in comps.get(cname, []):
            operands = _operand_args(ins.args_text)
            attrs = ins.args_text[len(operands):]
            c = Cost()
            op = ins.opcode

            if op == "while":
                body = _CALLED_RE.search(attrs)
                cond = _COND_RE.search(attrs)
                trip = 1
                mt = _TRIP_RE.search(attrs)
                if mt:
                    trip = int(mt.group(1))
                inner = Cost()
                if body:
                    inner += comp_cost(body.group(1))
                if cond:
                    inner += comp_cost(cond.group(1))
                c += inner.scaled(trip)
            elif op in ("call", "conditional", "map", "async-start"):
                for cc in _CALLED_RE.findall(attrs):
                    c += comp_cost(cc)
            elif op == "fusion":
                called = _CALLED_RE.search(attrs)
                if called and is_pure_convert_fusion(called.group(1)):
                    total += Cost()
                    continue
                if called:
                    interior = comp_cost(called.group(1))
                    c.flops += interior.flops
                    c.collective_bytes += interior.collective_bytes
                    for k, v in interior.collective_per_op.items():
                        c.collective_per_op[k] = (
                            c.collective_per_op.get(k, 0) + v)
                    for k, v in interior.collective_counts.items():
                        c.collective_counts[k] = (
                            c.collective_counts.get(k, 0) + v)
                    c.bytes += (fusion_output_bytes(called.group(1),
                                                    ins.result_text)
                                + fusion_input_bytes(called.group(1)))
                else:
                    c.bytes += (_bytes_of_shapes(ins.result_text)
                                + operand_bytes(cname, operands))
            elif op in _COLLECTIVE_OPCODES:
                base = op.replace("-start", "")
                ob = operand_bytes(cname, operands)
                if ob == 0:
                    ob = _bytes_of_shapes(ins.result_text)
                c.collective_bytes += ob
                c.collective_per_op[base] = c.collective_per_op.get(base, 0) + ob
                c.collective_counts[base] = c.collective_counts.get(base, 0) + 1
                c.bytes += ob + _bytes_of_shapes(ins.result_text)
            elif op == "dot":
                rs = _first_shape(ins.result_text)
                result_elems = math.prod(rs[1]) if rs else 0
                lhs = first_operand_shape(cname, operands)
                k = 1
                mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
                if lhs and mcd and mcd.group(1):
                    for idx in mcd.group(1).split(","):
                        i = int(idx)
                        if i < len(lhs[1]):
                            k *= lhs[1][i]
                c.flops += 2.0 * result_elems * k
                c.bytes += (_bytes_of_shapes(ins.result_text)
                            + operand_bytes(cname, operands))
            elif op in ELEMENTWISE:
                rs = _first_shape(ins.result_text)
                c.flops += math.prod(rs[1]) if rs else 0
                c.bytes += (_bytes_of_shapes(ins.result_text)
                            + operand_bytes(cname, operands))
            elif op in ("reduce", "reduce-precision"):
                ob = operand_bytes(cname, operands)
                fs = first_operand_shape(cname, operands)
                c.flops += math.prod(fs[1]) if fs else 0
                c.bytes += ob + _bytes_of_shapes(ins.result_text)
            elif op in ("slice", "dynamic-slice", "gather"):
                # read + write only the sliced region, not the full operand
                c.bytes += 2 * _bytes_of_shapes(ins.result_text)
            elif op == "dynamic-update-slice":
                # in-place DUS: read + write the update region only
                refs = _REF_RE.findall(operands)
                upd = 0
                if len(refs) >= 2 and refs[1] in symtab[cname]:
                    upd = _bytes_of_shapes(symtab[cname][refs[1]])
                c.bytes += 2 * upd if upd else _bytes_of_shapes(ins.result_text)
            elif op == "scatter":
                refs = _REF_RE.findall(operands)
                upd = sum(_bytes_of_shapes(symtab[cname][r]) for r in refs[1:]
                          if r in symtab[cname])
                c.bytes += 2 * upd if upd else _bytes_of_shapes(ins.result_text)
            elif op == "broadcast":
                c.bytes += _bytes_of_shapes(ins.result_text)
            elif op in FREE_OPS:
                pass
            elif op in MOVEMENT_OPS:
                c.bytes += (_bytes_of_shapes(ins.result_text)
                            + operand_bytes(cname, operands))
            else:
                c.bytes += (_bytes_of_shapes(ins.result_text)
                            + operand_bytes(cname, operands))
            total += c
        memo[cname] = total
        return total

    return comp_cost(entry)


def analyze_compiled(compiled) -> dict:
    cost = analyze(compiled.as_text())
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.collective_bytes,
        "collective_per_op": cost.collective_per_op,
        "collective_counts": cost.collective_counts,
    }
