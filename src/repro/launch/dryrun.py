import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- multi-pod dry-run: lower + compile every (arch x shape x mesh) cell ---
# (the two lines above MUST precede any other import — jax locks the device
# count at first init)

import argparse        # noqa: E402
import json            # noqa: E402
import math            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import (  # noqa: E402
    ARCH_IDS, SHAPE_CELLS, TrainConfig, get_config)
from ..distributed.sharding import axis_rules, logical_to_spec  # noqa: E402
from ..models import (  # noqa: E402
    decode_inputs_specs, get_api, train_batch_specs)
from ..train import adamw_init, build_train_step  # noqa: E402
from ..train.train_step import build_decode_step, build_prefill  # noqa: E402
from .hlo_analysis import analyze_compiled  # noqa: E402
from .mesh import build_rules, make_production_mesh, param_shardings  # noqa: E402

# v5e-class hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in post-optimization HLO.

    Convention: for each collective instruction line we sum the *operand*
    shapes (everything after the opcode); this is the per-device payload
    entering the collective.
    """
    per_op = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in COLLECTIVE_OPS:
            # match "= TYPE[...] op-name(" or fusion-wrapped "op-name."
            marker = f" {op}("
            start_marker = f"{op}-start("
            if marker not in stripped and start_marker not in stripped:
                continue
            idx = stripped.find(marker)
            if idx < 0:
                idx = stripped.find(start_marker)
            args = stripped[idx:]
            shapes = _SHAPE_RE.findall(args)
            if not shapes:
                # operands given as %refs only; fall back to the result shape
                shapes = _SHAPE_RE.findall(stripped.split("=")[1] if "="
                                           in stripped else stripped)[:1]
            per_op[op] += sum(_shape_bytes(d, s) for d, s in shapes)
            counts[op] += 1
            break
    total = sum(per_op.values())
    return {"per_op": per_op, "counts": counts, "total_bytes": total}


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode: per step."""
    api = get_api(cfg)
    params = jax.eval_shape(lambda: api.init_params(jax.random.key(0), cfg))
    n_total = sum(math.prod(x.shape) for x in jax.tree.leaves(params))
    n = n_total
    if cfg.moe is not None:
        m = cfg.moe
        routed = sum(
            math.prod(x.shape)
            for k in ("wg", "wu", "wd")
            for x in jax.tree.leaves(params["moe_layers"]["moe"][k])
        ) if "moe_layers" in params else 0
        # keep top_k of n_experts of the routed weights active
        n = n_total - routed + routed * m.top_k / m.n_experts
    tokens = (cell.global_batch * cell.seq_len if cell.kind != "decode"
              else cell.global_batch)  # decode: one token per sequence
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n * tokens


def _applicable(cfg, cell) -> tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k-token decode needs "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""


def _batch_shardings(batch_specs_tree, mesh):
    def spec_for(path_leaf_name, leaf):
        if leaf.ndim >= 1:
            names = ["batch"] + [None] * (leaf.ndim - 1)
            return NamedSharding(mesh, logical_to_spec(names))
        return NamedSharding(mesh, P())
    return jax.tree.map(lambda l: spec_for(None, l), batch_specs_tree)


def _cache_shardings(cache_abs, cache_spec_tree, mesh):
    spec_leaves = jax.tree.leaves(
        cache_spec_tree, is_leaf=lambda s: isinstance(s, tuple))
    abs_leaves, treedef = jax.tree_util.tree_flatten(cache_abs)
    shardings = [
        NamedSharding(mesh, logical_to_spec(s)) for s in spec_leaves]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def microbatch_for(cfg, cell, multi_pod: bool) -> int:
    if cell.kind != "train":
        return 0
    # Fewer, larger microbatches cut gradient-accumulation traffic (each
    # accumulation pass reads+writes the full f32 grad buffer — §Perf D2:
    # deepseek memory term −22% going 16 -> 4). Bounded below by activation
    # memory: granite-34b / llama4 need 16 slices to stay inside ~14 GB temp.
    # Slices must stay divisible by total DP (16 single-pod, 32 multi-pod).
    heavy = {"granite-34b", "llama4-maverick-400b-a17b"}
    if cfg.arch_id in heavy:
        return 8 if multi_pod else 16
    if os.environ.get("REPRO_NAIVE", "0") == "1":
        return 8 if multi_pod else 16    # the pre-D2 baseline
    return 4 if cfg.arch_id.startswith("deepseek") else 8


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                verbose: bool = True) -> dict:
    cfg = get_config(arch)
    cell = next(c for c in SHAPE_CELLS if c.name == shape_name)
    api = get_api(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)

    ok, reason = _applicable(cfg, cell)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips, "status": "skipped", "reason": reason,
    }
    if not ok:
        return result

    rules = build_rules(cfg, cell, multi_pod=multi_pod)
    t0 = time.time()
    with mesh, axis_rules(rules, mesh=mesh):
        params_abs = jax.eval_shape(
            lambda: api.init_params(jax.random.key(0), cfg, jnp.bfloat16))
        p_shard = param_shardings(mesh, api.param_specs(cfg))

        if cell.kind == "train":
            tcfg = TrainConfig(seq_len=cell.seq_len,
                               global_batch=cell.global_batch,
                               microbatch=microbatch_for(cfg, cell, multi_pod),
                               compute_dtype="bfloat16",
                               remat=os.environ.get("REPRO_REMAT", "full"))
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            opt_shard = jax.tree.map(
                lambda _s, ps: ps, opt_abs.mu, p_shard)
            from ..train.optimizer import AdamWState
            opt_sharding = AdamWState(
                step=NamedSharding(mesh, P()), mu=opt_shard, nu=opt_shard)
            batch_abs = train_batch_specs(cfg, cell.global_batch, cell.seq_len)
            b_shard = _batch_shardings(batch_abs, mesh)
            step = build_train_step(cfg, tcfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, opt_sharding, b_shard),
                             out_shardings=(p_shard, opt_sharding, None))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif cell.kind == "prefill":
            max_len = cell.seq_len + (cfg.n_prefix_tokens or 0)
            fn = build_prefill(cfg, max_len)
            batch_abs = train_batch_specs(cfg, cell.global_batch, cell.seq_len)
            batch_abs.pop("labels")
            b_shard = _batch_shardings(batch_abs, mesh)
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            cache_len = cell.seq_len + (cfg.n_prefix_tokens or 0)
            inputs = decode_inputs_specs(cfg, cell.global_batch, cache_len)
            cache_sh = _cache_shardings(inputs["cache"], api.cache_specs(cfg),
                                        mesh)
            tok_sh = NamedSharding(mesh, logical_to_spec(["batch", None]))
            pos_sh = NamedSharding(mesh, P())
            extras = inputs.get("extras")
            fn = build_decode_step(cfg)
            if extras is not None:
                ex_sh = {"enc_out": NamedSharding(
                    mesh, logical_to_spec(["batch", None, None]))}
                jitted = jax.jit(fn, in_shardings=(p_shard, tok_sh, cache_sh,
                                                   pos_sh, ex_sh))
                lowered = jitted.lower(params_abs, inputs["tokens"],
                                       inputs["cache"], inputs["pos"], extras)
            else:
                jitted = jax.jit(fn, in_shardings=(p_shard, tok_sh, cache_sh,
                                                   pos_sh))
                lowered = jitted.lower(params_abs, inputs["tokens"],
                                       inputs["cache"], inputs["pos"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # XLA's aggregate numbers (NO trip-count scaling — kept for reference)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax wraps per-partition dicts in a list
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    # trip-count-aware analysis (hlo_analysis.py) — the roofline source
    t0 = time.time()
    acc = analyze_compiled(compiled)
    t_analyze = time.time() - t0
    flops = acc["flops"]
    bytes_accessed = acc["bytes"]
    coll_bytes = acc["collective_bytes"]

    # --- roofline terms (per-chip seconds; the compiled module is the
    # per-device SPMD program, so its costs are already per-device)
    mf = model_flops(cfg, cell)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]

    result.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "collectives": {"per_op": acc["collective_per_op"],
                        "counts": acc["collective_counts"]},
        "xla_cost_analysis": {"flops": xla_flops, "bytes": xla_bytes,
                              "note": "while bodies counted once by XLA"},
        "memory_analysis": mem_d,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
        },
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_compute_ratio": (mf / n_chips) / flops if flops else 0.0,
    })
    if verbose:
        print(json.dumps({k: result[k] for k in
                          ("arch", "shape", "mesh", "status", "compile_s")},
                         indent=None))
        print(f"  memory_analysis: {mem_d}")
        print(f"  cost: flops/dev={flops:.3e} bytes/dev={bytes_accessed:.3e} "
              f"coll/dev={coll_bytes:.3e}")
        print(f"  roofline: compute={compute_s*1e3:.2f}ms "
              f"memory={memory_s*1e3:.2f}ms "
              f"collective={collective_s*1e3:.2f}ms dominant={dominant} "
              f"useful={result['useful_compute_ratio']:.3f}")
    return result


# ---------------------------------------------------------------------------
# GPIC cells: the paper's own technique on the production mesh
# ---------------------------------------------------------------------------

GPIC_CELLS = {
    # name: (engine, n_points, n_features)
    "explicit_262k": ("explicit", 262_144, 64),
    "streaming_1m": ("streaming", 1_048_576, 64),
    "matrixfree_4m": ("matrix_free", 4_194_304, 64),
}


def dryrun_gpic(shape_name: str, *, multi_pod: bool,
                verbose: bool = True) -> dict:
    """Lower + compile distributed GPIC on the production mesh.

    The convergence while-loop has no static trip count, so the analyzer
    reports [affinity build + ONE power iteration] — the natural per-step
    unit for a convergence loop (EXPERIMENTS.md §Roofline notes this).
    """
    from ..core import GPICConfig, run_gpic

    variant, n, m = GPIC_CELLS[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)
    axes = mesh.axis_names  # shard rows over ALL axes (pod, data, model)

    result = {"arch": f"gpic-{variant}", "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "chips": n_chips, "status": "skipped", "reason": ""}

    x_abs = jax.ShapeDtypeStruct((n, m), jnp.float32)
    key_abs = jax.ShapeDtypeStruct((), jnp.uint32)
    x_sh = NamedSharding(mesh, P(axes))
    key_sh = NamedSharding(mesh, P())

    naive = os.environ.get("REPRO_NAIVE", "0") == "1"
    cfg = GPICConfig(engine=variant, mesh=mesh, shard_axes=axes,
                     affinity_kind="cosine_shifted", max_iter=50)
    if variant == "explicit" and not naive:
        cfg = cfg.with_(a_dtype=jnp.bfloat16,             # opt O4
                        fold_shift=True)                  # opt O5
    fn = lambda x, key: run_gpic(x, 4, cfg, key=key)

    t0 = time.time()
    with mesh:
        key_abs = jax.eval_shape(lambda: jax.random.key(0))
        lowered = jax.jit(fn, in_shardings=(x_sh, key_sh)).lower(x_abs, key_abs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_d = {"argument_bytes": mem.argument_size_in_bytes,
                 "output_bytes": mem.output_size_in_bytes,
                 "temp_bytes": mem.temp_size_in_bytes}
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}
    acc = analyze_compiled(compiled)
    flops, bytes_accessed, coll_bytes = (acc["flops"], acc["bytes"],
                                         acc["collective_bytes"])
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    # "model flops" for GPIC: affinity 2n²m/P + one matvec 2n²/P (explicit;
    # streaming does the same arithmetic, regenerated inside the sweep) or
    # 4nm/P per iteration (matrix-free)
    if variant in ("explicit", "streaming"):
        mf = (2.0 * n * n * m + 2.0 * n * n) / n_chips
    else:
        mf = 8.0 * n * m / n_chips
    result.update({
        "status": "ok", "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "collectives": {"per_op": acc["collective_per_op"],
                        "counts": acc["collective_counts"]},
        "memory_analysis": mem_d,
        "roofline": {"compute_s": compute_s, "memory_s": memory_s,
                     "collective_s": collective_s, "dominant": dominant},
        "model_flops_per_device": mf,
        "useful_compute_ratio": mf / flops if flops else 0.0,
        "model_flops_global": mf * n_chips,
        "note": "cost unit = affinity build + 1 power iteration "
                "(unknown trip count)",
    })
    if verbose:
        print(f"  gpic-{variant}: compile={t_compile:.1f}s "
              f"compute={compute_s*1e3:.2f}ms memory={memory_s*1e3:.2f}ms "
              f"collective={collective_s*1e3:.2f}ms dominant={dominant}")
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    gpic_names = [f"gpic:{s}" for s in GPIC_CELLS]
    ap.add_argument("--arch", choices=list(ARCH_IDS) + ["gpic"])
    ap.add_argument("--shape",
                    choices=[c.name for c in SHAPE_CELLS] + list(GPIC_CELLS))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--naive", action="store_true",
                    help="disable beyond-baseline optimizations (REPRO_NAIVE)")
    args = ap.parse_args()
    if args.naive:
        os.environ["REPRO_NAIVE"] = "1"

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = [(a, s.name) for a in ARCH_IDS for s in SHAPE_CELLS]
        cells += [("gpic", s) for s in GPIC_CELLS]
    else:
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            out_path = os.path.join(args.out, tag + ".json")
            if os.path.exists(out_path):
                print(f"[skip existing] {tag}")
                continue
            print(f"=== {tag} ===", flush=True)
            try:
                if arch == "gpic":
                    res = dryrun_gpic(shape, multi_pod=mp)
                else:
                    res = dryrun_cell(arch, shape, multi_pod=mp)
            except Exception as e:
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                failures.append(tag)
                print(f"  ERROR {type(e).__name__}: {e}", flush=True)
            with open(out_path, "w") as f:
                json.dump(res, f, indent=2)
    if failures:
        print(f"\nFAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells compiled")


if __name__ == "__main__":
    main()
