"""Serving driver: prefill a batch of requests, then batched greedy decode.

CPU-scale demo:
    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..models import get_api, make_train_batch
from ..train.train_step import build_decode_step, build_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = get_api(cfg)
    params = api.init_params(jax.random.key(args.seed), cfg)
    max_len = args.prompt_len + args.gen + (cfg.n_prefix_tokens or 0)

    batch = make_train_batch(cfg, args.batch, args.prompt_len, args.seed)
    batch.pop("labels")
    prefill = jax.jit(build_prefill(cfg, max_len, compute_dtype=jnp.float32))
    decode = jax.jit(build_decode_step(cfg, compute_dtype=jnp.float32))

    t0 = time.perf_counter()
    out = prefill(params, batch)
    logits, cache = out[0], out[1]
    extras = {"enc_out": out[2]} if cfg.family == "encdec" else None
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
    tok = tok.astype(jnp.int32)
    pos = jnp.int32(args.prompt_len + (cfg.n_prefix_tokens
                                       if cfg.family == "vlm" else 0))
    generated = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        nxt, cache = decode(params, tok, cache, pos + i, extras)
        tok = nxt[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    seqs = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.arch_id} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   "
          f"decode: {t_decode/max(args.gen-1,1)*1e3:.1f} ms/token")
    for i in range(min(args.batch, 2)):
        print(f"  seq{i}: {seqs[i].tolist()}")


if __name__ == "__main__":
    main()
