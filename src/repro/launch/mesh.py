"""Production mesh + per-(arch, cell) logical-axis rule construction.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state). Single pod: (data=16, model=16) = 256 chips; multi-pod adds a
leading pod axis: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

from typing import Optional

import jax

from ..configs.base import ModelConfig, ShapeCell


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"production mesh needs {n} devices, have {len(devs)} — the "
            "dry-run entry point sets XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    try:  # AxisType landed in jax 0.5; older jax defaults to Auto anyway
        from jax.sharding import AxisType
        kw = {"axis_types": (AxisType.Auto,) * len(axes)}
    except ImportError:
        kw = {}
    return jax.make_mesh(shape, axes, devices=devs[:n], **kw)


def _div(n: int, by: int) -> bool:
    return n > 0 and n % by == 0


def build_rules(cfg: ModelConfig, cell: Optional[ShapeCell] = None,
                *, multi_pod: bool = False,
                model_size: int = 16, data_size: int = 16,
                overrides: Optional[dict] = None) -> dict:
    """Megatron-style logical->mesh rules, specialized per arch and cell.

    Activation axes ("*_act") only map to a mesh axis when the runtime dim
    divides it; parameter axes are flattened head*dim products which always
    divide for the assigned archs. batch=1 cells idle the data axis and
    (where possible) shard the KV-cache sequence dim over it instead.
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    total_dp = data_size * (2 if multi_pod else 1)

    batch = cell.global_batch if cell else None
    rules: dict = {
        # params
        "layers": None,
        "embed": None,
        "heads": "model",        # flattened n_heads*head_dim param dim
        "kv_heads": "model",     # flattened kv*head_dim param dim
        "mlp": "model",
        "vocab": "model",
        "experts": "model",      # EP
        "expert_mlp": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        # activations
        "batch": dp,
        "seq": None,
        "cache_seq": None,
        "heads_act": "model" if _div(cfg.n_heads, model_size) else None,
        "kv_heads_act": "model" if _div(cfg.n_kv_heads, model_size) else None,
    }

    if batch is not None and not _div(batch, total_dp):
        # batch unshardable (e.g. long_500k batch=1): idle the data axis for
        # activations; shard the cache sequence dim over it instead (the
        # flash-decoding layout) when the cell is a decode cell.
        rules["batch"] = None
        if cell and cell.kind == "decode":
            rules["cache_seq"] = dp
    import os
    naive = os.environ.get("REPRO_NAIVE", "0") == "1"
    if (cell and cell.kind == "decode" and rules["kv_heads_act"] is None
            and not naive):
        # opt H2 (flash-decoding layout): when kv heads cannot shard over
        # "model" (MQA / non-divisible head counts), shard the cache SEQ dim
        # there instead — otherwise the cache is replicated 16x and decode
        # reads are 16x the roofline minimum.
        cs = rules.get("cache_seq")
        existing = () if cs is None else ((cs,) if isinstance(cs, str) else
                                          tuple(cs))
        flat = []
        for a in existing:
            flat.extend(a if isinstance(a, tuple) else (a,))
        rules["cache_seq"] = tuple(flat) + ("model",)
    if overrides:
        rules.update(overrides)
    return rules


def param_shardings(mesh, specs_tree):
    """Logical-spec pytree -> NamedSharding pytree (under active rules)."""
    from jax.sharding import NamedSharding

    from ..distributed.sharding import logical_to_spec

    def to_sharding(spec):
        return NamedSharding(mesh, logical_to_spec(spec))

    return jax.tree.map(to_sharding, specs_tree,
                        is_leaf=lambda s: isinstance(s, tuple))
