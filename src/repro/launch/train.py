"""End-to-end training driver.

CPU-scale example (the "train a ~100M model for a few hundred steps" driver):
    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
        --steps 200 --batch 8 --seq 128

Production shape (mesh + shardings, requires the 256/512-device environment):
    python -m repro.launch.train --arch granite-34b --mesh single ...

Features: restartable loop (checkpoint/restart), failure injection,
straggler monitoring, optional int8 gradient compression.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, TrainConfig, get_config, get_smoke_config
from ..data.tokens import SyntheticTokenStream
from ..models import get_api
from ..train import adamw_init, build_train_step
from ..train.fault_tolerance import FailureInjector, RestartableLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        seq_len=args.seq, global_batch=args.batch, microbatch=args.microbatch,
        learning_rate=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
        total_steps=args.steps, compute_dtype="float32",
        gradient_compression=args.compress_grads, seed=args.seed,
        remat="none" if args.smoke else "full")

    api = get_api(cfg)
    params = api.init_params(jax.random.key(args.seed), cfg)
    opt = adamw_init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_params:,} "
          f"batch={args.batch}x{args.seq}")

    stream = SyntheticTokenStream(cfg.vocab_size, seed=args.seed)
    step_jit = jax.jit(build_train_step(cfg, tcfg))

    def step_fn(state, batch):
        p, o = state
        p, o, m = step_jit(p, o, batch)
        return (p, o), m

    def data_fn(step):
        b = stream.batch_at(step, args.batch, args.seq)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "encdec":
            b["src_embeds"] = jax.random.normal(
                jax.random.key(step), (args.batch, args.seq, cfg.d_model)) * 0.02
        if cfg.family == "vlm":
            b["image_embeds"] = jax.random.normal(
                jax.random.key(step),
                (args.batch, cfg.n_prefix_tokens, cfg.d_model)) * 0.02
        return b

    injector = None
    if args.inject_failure_at >= 0:
        injector = FailureInjector(fail_at_steps=[args.inject_failure_at])

    loop = RestartableLoop(step_fn, data_fn, args.ckpt_dir,
                           ckpt_every=args.ckpt_every, injector=injector)
    t0 = time.time()
    state, step, log = loop.run((params, opt), args.steps)
    wall = time.time() - t0

    for rec in log[:: max(args.log_every, 1)]:
        print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
              f"lr {rec['lr']:.2e} gnorm {rec['grad_norm']:.2f} "
              f"{rec['sec']*1e3:.0f}ms")
    first = log[0]["loss"] if log else float("nan")
    last = log[-1]["loss"] if log else float("nan")
    print(f"done: {step} steps in {wall:.1f}s; loss {first:.4f} -> {last:.4f};"
          f" restarts={loop.restarts} stragglers={len(loop.monitor.flagged)}")
    summary = {"arch": cfg.arch_id, "steps": step, "loss_first": float(first),
               "loss_last": float(last), "wall_s": wall,
               "restarts": loop.restarts}
    os.makedirs(args.ckpt_dir, exist_ok=True)
    with open(os.path.join(args.ckpt_dir, "summary.json"), "w") as f:
        json.dump(summary, f)


if __name__ == "__main__":
    main()
