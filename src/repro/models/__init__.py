from .model_zoo import (
    ModelAPI,
    decode_inputs_specs,
    get_api,
    make_train_batch,
    train_batch_specs,
)

__all__ = [
    "ModelAPI",
    "get_api",
    "train_batch_specs",
    "decode_inputs_specs",
    "make_train_batch",
]
