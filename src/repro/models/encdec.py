"""Encoder-decoder backbone (seamless-m4t-large-v2).

Per the assignment the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (b, s_src, d_model). The backbone is
n_enc_layers of bidirectional self-attention + n_layers decoder layers of
causal self-attention + cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from .layers import rms_norm


def init_enc_layer(key, cfg: ModelConfig, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k2, cfg, dtype, gated=False),
    }


def init_dec_layer(key, cfg: ModelConfig, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "self_attn": L.init_attention(k1, cfg, dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "cross_attn": L.init_attention(k2, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k3, cfg, dtype, gated=False),
    }


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ke, k1, k2, kf = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.n_enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg, dtype))(dec_keys),
        "ln_enc": jnp.ones((cfg.d_model,), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }


def param_specs(cfg: ModelConfig):
    leaf = lambda s: isinstance(s, tuple)
    enc = {
        "ln1": ("embed",), "attn": L.attention_specs(cfg),
        "ln2": ("embed",), "mlp": L.mlp_specs(gated=False),
    }
    dec = {
        "ln1": ("embed",), "self_attn": L.attention_specs(cfg),
        "ln_x": ("embed",), "cross_attn": L.attention_specs(cfg),
        "ln2": ("embed",), "mlp": L.mlp_specs(gated=False),
    }
    stack = lambda t: jax.tree.map(lambda s: ("layers",) + tuple(s), t,
                                   is_leaf=leaf)
    return {
        "embed": L.embed_specs(cfg),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "ln_enc": ("embed",),
        "ln_f": ("embed",),
    }


def encode(params, cfg: ModelConfig, src_embeds, *, compute_dtype=jnp.bfloat16,
           remat: str = "full"):
    h = src_embeds.astype(compute_dtype)
    positions = jnp.arange(h.shape[1])

    def body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        a, _ = L.attention(rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"],
                           cfg, positions=positions, causal=False)
        x = x + a
        x = x + L.mlp(rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
        return x, None

    if remat in ("full", "dots"):
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return rms_norm(h, params["ln_enc"].astype(compute_dtype), cfg.norm_eps)


def _dec_layer(cfg, x, lp, enc_out, *, positions, cache=None, cache_pos=None):
    a, nc = L.attention(rms_norm(x, lp["ln1"], cfg.norm_eps), lp["self_attn"],
                        cfg, positions=positions, cache=cache,
                        cache_pos=cache_pos)
    x = x + a
    c, _ = L.attention(rms_norm(x, lp["ln_x"], cfg.norm_eps), lp["cross_attn"],
                       cfg, x_kv=enc_out, rope=False)
    x = x + c
    x = x + L.mlp(rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
    return x, nc


def decode_train(params, cfg: ModelConfig, enc_out, tgt_tokens,
                 *, compute_dtype=jnp.bfloat16, remat: str = "full"):
    h = L.embed_tokens(params["embed"], tgt_tokens).astype(compute_dtype)
    positions = jnp.arange(h.shape[1])

    def body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        x, _ = _dec_layer(cfg, x, lp, enc_out, positions=positions)
        return x, None

    if remat in ("full", "dots"):
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return L.lm_logits(params["embed"], h.astype(jnp.float32))


def forward(params, cfg: ModelConfig, batch, *, compute_dtype=jnp.bfloat16,
            remat: str = "full"):
    """batch = {"src_embeds": (b, s_src, d), "tokens": (b, s_tgt)}."""
    enc_out = encode(params, cfg, batch["src_embeds"],
                     compute_dtype=compute_dtype, remat=remat)
    return decode_train(params, cfg, enc_out, batch["tokens"],
                        compute_dtype=compute_dtype, remat=remat)


# ---------------------------------------------------------------------------
# serving: decoder decode step against cached encoder output
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch, max_len, dtype=jnp.bfloat16):
    one = L.init_attention_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)


def cache_specs(cfg: ModelConfig):
    leaf = lambda s: isinstance(s, tuple)
    return jax.tree.map(lambda s: ("layers",) + tuple(s),
                        L.attention_cache_specs(cfg), is_leaf=leaf)


def decode_step(params, cfg: ModelConfig, tokens, cache, pos, enc_out,
                *, compute_dtype=jnp.bfloat16):
    h = L.embed_tokens(params["embed"], tokens).astype(compute_dtype)
    positions = pos + jnp.arange(tokens.shape[1])

    def body(x, scanned):
        lp, lc = scanned
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        x, nc = _dec_layer(cfg, x, lp, enc_out, positions=positions,
                           cache=lc, cache_pos=pos)
        return x, nc

    h, new_cache = jax.lax.scan(body, h, (params["dec_layers"], cache))
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return L.lm_logits(params["embed"], h.astype(jnp.float32)), new_cache


def prefill(params, cfg: ModelConfig, batch, max_len,
            *, compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16):
    """Encode src and prefill the decoder self-attn cache with tgt tokens."""
    enc_out = encode(params, cfg, batch["src_embeds"],
                     compute_dtype=compute_dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    h = L.embed_tokens(params["embed"], tokens).astype(compute_dtype)
    positions = jnp.arange(s)

    def body(x, scanned):
        lp, lc = scanned
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        x, nc = _dec_layer(cfg, x, lp, enc_out, positions=positions,
                           cache=lc, cache_pos=0)
        return x, nc

    h, cache = jax.lax.scan(body, h, (params["dec_layers"], cache))
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return L.lm_logits(params["embed"], h.astype(jnp.float32)), cache, enc_out
