"""Mixture-of-Experts layers + Multi-head Latent Attention (MLA).

MoE dispatch is the TPU-standard *fixed-capacity sort* formulation: token
copies are sorted by expert id, packed into a static (E, C, d) buffer
(over-capacity copies dropped), run through batched expert GEMMs
(MXU-friendly einsum 'ecd,edf->ecf'), and scatter-added back weighted by the
router probabilities. All shapes static — compiles identically on 1 or 512
devices; experts shard over the "experts" logical axis (EP on the model axis).

MLA (DeepSeek-V2): KV compressed to a small latent (kv_lora_rank) plus one
shared RoPE key. Train/prefill use the naive expanded form; decode uses the
*absorbed* form attending directly over the compressed cache — the cache is
(b, s, kv_lora + rope_dim) regardless of head count.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig, ModelConfig, MoEConfig
from ..distributed.sharding import constrain
from .layers import dense_init, rms_norm

# ---------------------------------------------------------------------------
# routed experts
# ---------------------------------------------------------------------------


def init_moe_ffn(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),  # router in f32
        "wg": dense_init(ks[1], (e, d, f), d, dtype),
        "wu": dense_init(ks[2], (e, d, f), d, dtype),
        "wd": dense_init(ks[3], (e, f, d), f, dtype),
    }
    if m.n_shared_experts:
        fs = m.d_ff_expert * m.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": dense_init(kk[0], (d, fs), d, dtype),
            "wu": dense_init(kk[1], (d, fs), d, dtype),
            "wd": dense_init(kk[2], (fs, d), fs, dtype),
        }
    return p


def moe_ffn_specs(cfg: ModelConfig):
    s = {
        "router": ("embed", None),
        "wg": ("experts", "embed", "expert_mlp"),
        "wu": ("experts", "embed", "expert_mlp"),
        "wd": ("experts", "expert_mlp", "embed"),
    }
    if cfg.moe.n_shared_experts:
        s["shared"] = {"wg": ("embed", "mlp"), "wu": ("embed", "mlp"),
                       "wd": ("mlp", "embed")}
    return s


def moe_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    cap = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                        / max(cfg.n_experts, 1)))
    return max(cap, 4)


def moe_ffn(x, p, cfg: ModelConfig):
    """x (b, s, d) -> (y (b, s, d), aux_loss scalar).

    Under active sharding rules that map "experts" to a mesh axis, dispatch
    runs inside shard_map (explicit expert parallelism, opt H4): each model
    rank packs only the tokens routed to ITS local experts and the combine
    is ONE psum of (tokens, d) over the expert axis — versus the GSPMD-routed
    global sort/scatter whose collectives dominated the baseline roofline.
    """
    ep = _moe_ffn_ep(x, p, cfg)
    if ep is not None:
        return ep
    return _moe_ffn_local(x, p, cfg)


def _moe_ffn_local(x, p, cfg: ModelConfig):
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    xf = x.reshape(t, d)

    gates = (xf.astype(jnp.float32) @ p["router"])          # (t, e)
    probs = jax.nn.softmax(gates, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)                # (t, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                            # (e,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ids, e, dtype=jnp.float32), axis=1), axis=0)
    aux = jnp.sum(me * ce) * e * m.aux_loss_weight

    # ---- fixed-capacity packing (sorted by expert id)
    cap = moe_capacity(t, m)
    flat_e = top_ids.reshape(-1)                            # (t*k,)
    flat_src = jnp.repeat(jnp.arange(t), k)                 # (t*k,)
    flat_w = top_w.reshape(-1)

    order = jnp.argsort(flat_e)                             # stable
    e_sorted = flat_e[order]
    src_sorted = flat_src[order]
    w_sorted = flat_w[order]

    counts = jnp.bincount(flat_e, length=e)                 # (e,)
    starts = jnp.cumsum(counts) - counts                    # exclusive
    pos_in_e = jnp.arange(t * k) - starts[e_sorted]
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, e * cap)  # overflow row

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[src_sorted], mode="drop",
                           unique_indices=True)
    he = buf[: e * cap].reshape(e, cap, d)

    hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", he, p["wg"]))
    hu = jnp.einsum("ecd,edf->ecf", he, p["wu"])
    ye = jnp.einsum("ecf,efd->ecd", hg * hu, p["wd"])       # (e, cap, d)
    ye = constrain(ye, "experts", None, "embed")

    yflat = ye.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None],
                        yflat[jnp.minimum(slot, e * cap - 1)]
                        * w_sorted[:, None].astype(x.dtype),
                        0.0)
    y = jnp.zeros((t, d), x.dtype).at[src_sorted].add(contrib)

    if m.n_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(xf @ sp["wg"]) * (xf @ sp["wu"])) @ sp["wd"]
    return y.reshape(b, s, d), aux


def _moe_ffn_ep(x, p, cfg: ModelConfig):
    """Expert-parallel MoE via shard_map (see moe_ffn docstring). Returns
    None when no mesh/rules are active (smoke tests use the local path)."""
    from ..distributed.sharding import (
        current_mesh, current_rules, logical_to_spec)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..distributed.sharding import naive_mode
    mesh = current_mesh()
    rules = current_rules()
    if (mesh is None or rules is None or not rules.get("experts")
            or naive_mode()):
        return None
    m = cfg.moe
    ep_axes = rules["experts"]
    ep_axes = (ep_axes,) if isinstance(ep_axes, str) else tuple(ep_axes)
    ep_size = 1
    for a in ep_axes:
        ep_size *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    if m.n_experts % ep_size != 0:
        return None

    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    e_loc = e // ep_size

    x_spec = logical_to_spec(("batch", None, None))
    w_spec = P(ep_axes[0] if len(ep_axes) == 1 else ep_axes, None, None)
    r_spec = P()
    batch_axes = x_spec[0]
    batch_axes = (() if batch_axes is None else
                  ((batch_axes,) if isinstance(batch_axes, str)
                   else tuple(batch_axes)))

    def fn(x_l, router, wg, wu, wd):
        b_l = x_l.shape[0]
        t_l = b_l * s
        xf = x_l.reshape(t_l, d)
        my_rank = jax.lax.axis_index(ep_axes)

        gates = xf.astype(jnp.float32) @ router          # (t_l, e) — full E
        probs = jax.nn.softmax(gates, axis=-1)
        top_w, top_ids = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(
            jax.nn.one_hot(top_ids, e, dtype=jnp.float32), axis=1), axis=0)
        aux = jnp.sum(me * ce) * e * m.aux_loss_weight
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)   # global-batch average

        # keep only copies owned by this rank's expert slice
        flat_e = top_ids.reshape(-1)
        flat_src = jnp.repeat(jnp.arange(t_l), k)
        flat_w = top_w.reshape(-1)
        owner = flat_e // e_loc
        local_e = flat_e - my_rank * e_loc               # local expert id
        mine = owner == my_rank

        cap = moe_capacity(t_l, m) * 2   # headroom for routing imbalance
        order = jnp.argsort(jnp.where(mine, local_e, e_loc))
        e_sorted = jnp.where(mine, local_e, e_loc)[order]
        src_sorted = flat_src[order]
        w_sorted = flat_w[order]
        counts = jnp.bincount(jnp.where(mine, local_e, e_loc), length=e_loc + 1)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(t_l * k) - starts[e_sorted]
        keep = (e_sorted < e_loc) & (pos_in_e < cap)
        slot = jnp.where(keep, e_sorted * cap + pos_in_e, e_loc * cap)

        buf = jnp.zeros((e_loc * cap + 1, d), x_l.dtype)
        buf = buf.at[slot].set(xf[src_sorted], mode="drop",
                               unique_indices=True)
        he = buf[: e_loc * cap].reshape(e_loc, cap, d)
        hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", he, wg))
        hu = jnp.einsum("ecd,edf->ecf", he, wu)
        ye = jnp.einsum("ecf,efd->ecd", hg * hu, wd)
        yflat = ye.reshape(e_loc * cap, d)
        contrib = jnp.where(keep[:, None],
                            yflat[jnp.minimum(slot, e_loc * cap - 1)]
                            * w_sorted[:, None].astype(x_l.dtype), 0.0)
        y = jnp.zeros((t_l, d), x_l.dtype).at[src_sorted].add(contrib)
        y = jax.lax.psum(y, ep_axes)                     # combine expert ranks
        return y.reshape(b_l, s, d), aux

    y, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, r_spec, w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], p["wg"], p["wu"], p["wd"])

    if m.n_shared_experts:
        # shared expert stays OUTSIDE the shard_map: standard TP sharding
        # ("embed" x "mlp") with GSPMD-inserted collectives
        sp = p["shared"]
        from ..distributed.sharding import constrain as _c
        hs = jax.nn.silu(x @ sp["wg"]) * (x @ sp["wu"])
        hs = _c(hs, "batch", "seq", "mlp")
        y = y + _c(hs @ sp["wd"], "batch", "seq", "embed")
    return y, aux


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype=jnp.float32):
    a = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = a.nope_head_dim, a.rope_head_dim, a.v_head_dim, a.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * (dn + dr)), d, dtype),
        "w_dkv": dense_init(ks[1], (d, r), d, dtype),
        "w_kr": dense_init(ks[2], (d, dr), d, dtype),
        "kv_norm": jnp.ones((r,), dtype),
        "w_uk": dense_init(ks[3], (r, h * dn), r, dtype),
        "w_uv": dense_init(ks[4], (r, h * dv), r, dtype),
        "wo": dense_init(ks[5], (h * dv, d), h * dv, dtype),
    }


def mla_specs(cfg: ModelConfig):
    return {
        "wq": ("embed", "heads"),
        "w_dkv": ("embed", None),
        "w_kr": ("embed", None),
        "kv_norm": (None,),
        "w_uk": (None, "heads"),
        "w_uv": (None, "heads"),
        "wo": ("heads", "embed"),
    }


def _mla_rope(x, positions, theta):
    from .layers import apply_rope, rope_table
    cos, sin = rope_table(positions, x.shape[-1], theta)
    return apply_rope(x, cos, sin)


def mla_attention(x, p, cfg: ModelConfig, *, positions=None, cache=None,
                  cache_pos=None):
    """Naive (expanded) MLA for train/prefill; absorbed form for decode.

    cache: {"ckv": (b, S, r), "kr": (b, S, dr)} — compressed, head-free.
    """
    a = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv, r = a.nope_head_dim, a.rope_head_dim, a.v_head_dim, a.kv_lora_rank
    if positions is None:
        positions = jnp.arange(s)

    q = (x @ p["wq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    # cast back to compute dtype: RoPE's f32 tables must not promote the
    # score einsums (and the compressed cache) to f32
    q_rope = _mla_rope(q_rope, positions, cfg.rope_theta).astype(x.dtype)

    ckv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)   # (b,s,r)
    kr = _mla_rope((x @ p["w_kr"])[:, :, None, :], positions,
                   cfg.rope_theta)[:, :, 0].astype(x.dtype)       # (b,s,dr)

    scale = 1.0 / jnp.sqrt(jnp.asarray(dn + dr, jnp.float32))

    if cache is not None:
        ckv_c = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_pos, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["kr"], kr.astype(cache["kr"].dtype), (0, cache_pos, 0))
        cache = {"ckv": ckv_c, "kr": kr_c}
        s_kv = ckv_c.shape[1]
        # absorbed: q_eff = q_nope @ W_uk  (per head, into latent space)
        w_uk = p["w_uk"].reshape(r, h, dn)
        q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)        # (b,s,h,r)
        logits = (jnp.einsum("bshr,btr->bhst", q_eff, ckv_c)
                  + jnp.einsum("bshd,btd->bhst", q_rope, kr_c))
        logits = logits.astype(jnp.float32) * scale
        qi = cache_pos + jnp.arange(s)[:, None]
        kj = jnp.arange(s_kv)[None, :]
        mask = jnp.where(kj <= qi, 0.0, -jnp.inf).astype(jnp.float32)
        probs = jax.nn.softmax(logits + mask[None, None], -1).astype(x.dtype)
        lat = jnp.einsum("bhst,btr->bshr", probs, ckv_c)          # (b,s,h,r)
        w_uv = p["w_uv"].reshape(r, h, dv)
        out = jnp.einsum("bshr,rhd->bshd", lat, w_uv)             # (b,s,h,dv)
    else:
        k_nope = (ckv @ p["w_uk"]).reshape(b, s, h, dn)
        v = (ckv @ p["w_uv"]).reshape(b, s, h, dv)
        logits = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
                  + jnp.einsum("bshd,btd->bhst", q_rope, kr))
        logits = logits.astype(jnp.float32) * scale
        qi = jnp.arange(s)[:, None]
        kj = jnp.arange(s)[None, :]
        mask = jnp.where(kj <= qi, 0.0, -jnp.inf).astype(jnp.float32)
        probs = jax.nn.softmax(logits + mask[None, None], -1).astype(x.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, v)

    out = constrain(out, "batch", "seq", "heads", None)
    y = out.reshape(b, s, h * dv) @ p["wo"]
    return constrain(y, "batch", "seq", "embed"), cache


def init_mla_cache(cfg: ModelConfig, batch, max_len, dtype=jnp.bfloat16):
    a = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, a.rope_head_dim), dtype),
    }


def mla_cache_specs(cfg: ModelConfig):
    return {"ckv": ("batch", "cache_seq", None), "kr": ("batch", "cache_seq", None)}


# ---------------------------------------------------------------------------
# full MoE decoder LM (deepseek-v2-lite / llama4-maverick)
# ---------------------------------------------------------------------------


def _is_moe_layer(cfg: ModelConfig, idx: int) -> bool:
    m = cfg.moe
    if idx < m.first_dense:
        return False
    return (idx - m.first_dense) % m.moe_every == 0


def _uses_mla(cfg: ModelConfig) -> bool:
    return cfg.mla is not None


def init_layer(key, cfg: ModelConfig, moe_layer: bool, dtype=jnp.float32):
    from . import layers as L
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype),
         "ln2": jnp.ones((cfg.d_model,), dtype)}
    if _uses_mla(cfg):
        p["attn"] = init_mla(k1, cfg, dtype)
    else:
        p["attn"] = L.init_attention(k1, cfg, dtype)
    if moe_layer:
        p["moe"] = init_moe_ffn(k2, cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(k2, cfg, dtype, gated=True)
    return p


def layer_specs(cfg: ModelConfig, moe_layer: bool):
    from . import layers as L
    s = {"ln1": ("embed",), "ln2": ("embed",)}
    s["attn"] = mla_specs(cfg) if _uses_mla(cfg) else L.attention_specs(cfg)
    if moe_layer:
        s["moe"] = moe_ffn_specs(cfg)
    else:
        s["mlp"] = L.mlp_specs(gated=True)
    return s


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    """MoE models stack layers in (possibly) two scan groups: dense & moe.

    The layer schedule (which index is MoE) is static; we store two stacked
    pytrees plus the schedule so forward can scan each group.
    """
    from .layers import init_embed
    ke, kl = jax.random.split(key)
    keys = jax.random.split(kl, cfg.n_layers)
    moe_idx = [i for i in range(cfg.n_layers) if _is_moe_layer(cfg, i)]
    dense_idx = [i for i in range(cfg.n_layers) if i not in set(moe_idx)]
    params = {"embed": init_embed(ke, cfg, dtype),
              "ln_f": jnp.ones((cfg.d_model,), dtype)}
    if dense_idx:
        params["dense_layers"] = jax.vmap(
            lambda k: init_layer(k, cfg, False, dtype)
        )(jnp.stack([keys[i] for i in dense_idx]))
    if moe_idx:
        params["moe_layers"] = jax.vmap(
            lambda k: init_layer(k, cfg, True, dtype)
        )(jnp.stack([keys[i] for i in moe_idx]))
    return params


def layer_schedule(cfg: ModelConfig):
    """Returns list of ("dense"|"moe", group_position) in layer order."""
    sched = []
    nd = nm = 0
    for i in range(cfg.n_layers):
        if _is_moe_layer(cfg, i):
            sched.append(("moe", nm)); nm += 1
        else:
            sched.append(("dense", nd)); nd += 1
    return sched


def param_specs(cfg: ModelConfig):
    from .layers import embed_specs
    def stack(tree):
        return jax.tree.map(lambda s: ("layers",) + tuple(s), tree,
                            is_leaf=lambda s: isinstance(s, tuple))
    specs = {"embed": embed_specs(cfg), "ln_f": ("embed",)}
    sched = layer_schedule(cfg)
    if any(kind == "dense" for kind, _ in sched):
        specs["dense_layers"] = stack(layer_specs(cfg, False))
    if any(kind == "moe" for kind, _ in sched):
        specs["moe_layers"] = stack(layer_specs(cfg, True))
    return specs


def _apply_layer(cfg, x, lp, moe_layer, *, positions, cache=None, cache_pos=None):
    from . import layers as L
    h_in = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if _uses_mla(cfg):
        h, new_cache = mla_attention(h_in, lp["attn"], cfg,
                                     positions=positions, cache=cache,
                                     cache_pos=cache_pos)
    else:
        h, new_cache = L.attention(h_in, lp["attn"], cfg, positions=positions,
                                   cache=cache, cache_pos=cache_pos)
    x = x + h
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if moe_layer:
        y, aux = moe_ffn(h2, lp["moe"], cfg)
    else:
        y, aux = L.mlp(h2, lp["mlp"]), jnp.float32(0.0)
    return x + y, aux, new_cache


def _plan(cfg: ModelConfig):
    """Compile-friendly execution plan.

    Returns (n_prefix_dense, n_super, dense_per_super). Layer order:
      [first_dense dense] + n_super × [1 moe + (moe_every-1) dense].
    Supports the assigned patterns (deepseek: prefix 1 + all-moe;
    llama4: alternating moe/dense). Scanning super-layers keeps the HLO one
    moe + a few dense bodies regardless of depth.
    """
    m = cfg.moe
    rest = cfg.n_layers - m.first_dense
    assert rest % m.moe_every == 0, (
        f"n_layers-first_dense ({rest}) must divide moe_every ({m.moe_every})")
    return m.first_dense, rest // m.moe_every, m.moe_every - 1


def _cast(tree, compute_dtype):
    return jax.tree.map(
        lambda a: a.astype(compute_dtype) if a.dtype != jnp.float32 else a, tree)


def _remat_wrap(body, remat):
    if remat == "full":
        return jax.checkpoint(body)
    if remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return body


def forward(params, cfg: ModelConfig, tokens, *, compute_dtype=jnp.bfloat16,
            remat: str = "full", prefix_embeds=None, return_aux=False):
    from .layers import embed_tokens, lm_logits
    h = embed_tokens(params["embed"], tokens).astype(compute_dtype)
    positions = jnp.arange(h.shape[1])
    aux_total = jnp.float32(0.0)
    n_prefix, n_super, dense_per_super = _plan(cfg)

    def dense_body(x, lp):
        lp = _cast(lp, compute_dtype)
        x, aux, _ = _apply_layer(cfg, x, lp, False, positions=positions)
        return x, aux

    def super_body(x, lps):
        moe_lp, dense_lps = lps
        moe_lp = _cast(moe_lp, compute_dtype)
        x, aux, _ = _apply_layer(cfg, x, moe_lp, True, positions=positions)
        if dense_per_super:
            def inner(xx, dlp):
                dlp = _cast(dlp, compute_dtype)
                xx, a2, _ = _apply_layer(cfg, xx, dlp, False,
                                         positions=positions)
                return xx, a2
            x, a2s = jax.lax.scan(inner, x, dense_lps)
            aux = aux + jnp.sum(a2s)
        return x, aux

    dense = params.get("dense_layers")
    if n_prefix:
        pre = jax.tree.map(lambda a: a[:n_prefix], dense)
        h, auxs = jax.lax.scan(_remat_wrap(dense_body, remat), h, pre)
        aux_total = aux_total + jnp.sum(auxs)

    moe_stack = params["moe_layers"]
    if dense_per_super:
        rest = jax.tree.map(
            lambda a: a[n_prefix:].reshape(n_super, dense_per_super,
                                           *a.shape[1:]), dense)
    else:
        rest = None
    h, auxs = jax.lax.scan(_remat_wrap(super_body, remat), h,
                           (moe_stack, rest))
    aux_total = aux_total + jnp.sum(auxs)

    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    logits = lm_logits(params["embed"], h.astype(jnp.float32))
    if return_aux:
        return logits, aux_total
    return logits


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch, max_len, dtype=jnp.bfloat16):
    from . import layers as L
    if _uses_mla(cfg):
        one = init_mla_cache(cfg, batch, max_len, dtype)
    else:
        one = L.init_attention_cache(cfg, batch, max_len, dtype)
    n_prefix, n_super, dps = _plan(cfg)

    def rep(a, *lead):
        return jnp.broadcast_to(a[(None,) * len(lead)], tuple(lead) + a.shape)

    cache = {"moe": jax.tree.map(lambda a: rep(a, n_super), one)}
    if n_prefix:
        cache["prefix"] = jax.tree.map(lambda a: rep(a, n_prefix), one)
    if dps:
        cache["dense"] = jax.tree.map(lambda a: rep(a, n_super, dps), one)
    return cache


def cache_specs(cfg: ModelConfig):
    from . import layers as L
    base = mla_cache_specs(cfg) if _uses_mla(cfg) else L.attention_cache_specs(cfg)
    n_prefix, _n_super, dps = _plan(cfg)
    leaf = lambda s: isinstance(s, tuple)
    stack1 = jax.tree.map(lambda s: ("layers",) + tuple(s), base, is_leaf=leaf)
    stack2 = jax.tree.map(lambda s: ("layers", None) + tuple(s), base,
                          is_leaf=leaf)
    specs = {"moe": stack1}
    if n_prefix:
        specs["prefix"] = stack1
    if dps:
        specs["dense"] = stack2
    return specs


def _serve_scan(params, cfg, h, cache, pos, compute_dtype):
    """Group-scanned serving pass mirroring forward()'s plan."""
    n_prefix, n_super, dps = _plan(cfg)
    positions = pos + jnp.arange(h.shape[1])
    new_cache = dict(cache)
    dense = params.get("dense_layers")

    if n_prefix:
        pre = jax.tree.map(lambda a: a[:n_prefix], dense)

        def pre_body(x, scanned):
            lp, lc = scanned
            lp = _cast(lp, compute_dtype)
            x, _aux, nc = _apply_layer(cfg, x, lp, False, positions=positions,
                                       cache=lc, cache_pos=pos)
            return x, nc

        h, nc = jax.lax.scan(pre_body, h, (pre, cache["prefix"]))
        new_cache["prefix"] = nc

    moe_stack = params["moe_layers"]
    rest = (jax.tree.map(
        lambda a: a[n_prefix:].reshape(n_super, dps, *a.shape[1:]), dense)
        if dps else None)

    def super_body(x, scanned):
        moe_lp, dense_lps, moe_lc, dense_lcs = scanned
        moe_lp = _cast(moe_lp, compute_dtype)
        x, _aux, moe_nc = _apply_layer(cfg, x, moe_lp, True,
                                       positions=positions, cache=moe_lc,
                                       cache_pos=pos)
        if dps:
            def inner(xx, sc):
                dlp, dlc = sc
                dlp = _cast(dlp, compute_dtype)
                xx, _a, nc = _apply_layer(cfg, xx, dlp, False,
                                          positions=positions, cache=dlc,
                                          cache_pos=pos)
                return xx, nc
            x, dense_ncs = jax.lax.scan(inner, x, (dense_lps, dense_lcs))
        else:
            dense_ncs = dense_lcs
        return x, (moe_nc, dense_ncs)

    h, (moe_nc, dense_ncs) = jax.lax.scan(
        super_body, h,
        (moe_stack, rest, cache["moe"], cache.get("dense")))
    new_cache["moe"] = moe_nc
    if dps:
        new_cache["dense"] = dense_ncs
    return h, new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, pos,
                *, compute_dtype=jnp.bfloat16):
    from .layers import embed_tokens, lm_logits
    h = embed_tokens(params["embed"], tokens).astype(compute_dtype)
    h, cache = _serve_scan(params, cfg, h, cache, pos, compute_dtype)
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return lm_logits(params["embed"], h.astype(jnp.float32)), cache


def prefill(params, cfg: ModelConfig, tokens, max_len,
            *, compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16):
    from .layers import embed_tokens, lm_logits
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    h = embed_tokens(params["embed"], tokens).astype(compute_dtype)
    h, cache = _serve_scan(params, cfg, h, cache, jnp.int32(0), compute_dtype)
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return lm_logits(params["embed"], h.astype(jnp.float32)), cache
