"""Dense decoder-only transformer LM (granite / stablelm / danube / qwen and
the paligemma text backbone). Scan-over-layers + configurable remat.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from . import layers as L


def gated(cfg: ModelConfig) -> bool:
    return "mlp_nogate" not in cfg.notes


def init_layer(key, cfg: ModelConfig, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k2, cfg, dtype, gated=gated(cfg)),
    }


def layer_specs(cfg: ModelConfig):
    return {
        "ln1": ("embed",),
        "attn": L.attention_specs(cfg),
        "ln2": ("embed",),
        "mlp": L.mlp_specs(gated=gated(cfg)),
    }


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "layers": stacked,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }


def param_specs(cfg: ModelConfig):
    def stack(spec_tree):
        return jax.tree.map(lambda s: ("layers",) + tuple(s), spec_tree,
                            is_leaf=lambda s: isinstance(s, tuple))
    return {
        "embed": L.embed_specs(cfg),
        "layers": stack(layer_specs(cfg)),
        "ln_f": ("embed",),
    }


def _layer_apply(cfg, x, lp, *, positions, prefix_len, cache=None,
                 cache_pos=None):
    h, new_cache = L.attention(
        L.rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
        positions=positions, prefix_len=prefix_len,
        cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    x = x + L.mlp(L.rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
    return x, new_cache


def forward_embeds(
    params, cfg: ModelConfig, h, *, prefix_len=0,
    compute_dtype=jnp.bfloat16, remat: str = "full",
):
    """(b, s, e) embeddings -> (b, s, e) final hidden states."""
    h = h.astype(compute_dtype)
    positions = jnp.arange(h.shape[1])

    def body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        x, _ = _layer_apply(cfg, x, lp, positions=positions,
                            prefix_len=prefix_len)
        return x, None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return L.rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, compute_dtype=jnp.bfloat16,
            remat: str = "full", prefix_embeds=None):
    """tokens (b, s) -> logits (b, s, v). ``prefix_embeds`` (b, p, e) are
    prepended bidirectional positions (VLM/audio stub frontends)."""
    h = L.embed_tokens(params["embed"], tokens)
    prefix_len = 0
    if prefix_embeds is not None:
        prefix_len = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = forward_embeds(params, cfg, h, prefix_len=prefix_len,
                       compute_dtype=compute_dtype, remat=remat)
    if prefix_len:
        h = h[:, prefix_len:]
    return L.lm_logits(params["embed"], h.astype(jnp.float32))


# ---------------------------------------------------------------------------
# serving: prefill + decode with a stacked KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch, max_len, dtype=jnp.bfloat16):
    one = L.init_attention_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)


def cache_specs(cfg: ModelConfig):
    return jax.tree.map(lambda s: ("layers",) + tuple(s),
                        L.attention_cache_specs(cfg),
                        is_leaf=lambda s: isinstance(s, tuple))


def decode_step(params, cfg: ModelConfig, tokens, cache, pos,
                *, compute_dtype=jnp.bfloat16):
    """One token step. tokens (b, 1); cache stacked (L, b, S, kv, hd);
    pos scalar int32 — current write position. Returns (logits, cache)."""
    h = L.embed_tokens(params["embed"], tokens).astype(compute_dtype)
    positions = pos + jnp.arange(tokens.shape[1])

    def body(x, scanned):
        lp, lc = scanned
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        x, nc = _layer_apply(cfg, x, lp, positions=positions, prefix_len=0,
                             cache=lc, cache_pos=pos)
        return x, nc

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = L.rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h.astype(jnp.float32))
    return logits, new_cache


def prefill(params, cfg: ModelConfig, tokens, max_len,
            *, compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16):
    """Full-sequence forward that also fills the KV cache."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    h = L.embed_tokens(params["embed"], tokens).astype(compute_dtype)
    positions = jnp.arange(s)

    def body(x, scanned):
        lp, lc = scanned
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        x, nc = _layer_apply(cfg, x, lp, positions=positions, prefix_len=0,
                             cache=lc, cache_pos=0)
        return x, nc

    h, cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = L.rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h.astype(jnp.float32))
    return logits, cache
