"""PaliGemma-style VLM backbone: gemma decoder with an image-embedding prefix.

The SigLIP vision tower is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (b, n_patches, d_model) that are
prepended to the text tokens with bidirectional (prefix-LM) attention; text
positions attend causally. Decode runs against a cache whose first
``n_prefix_tokens`` positions were filled by the image prefix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import transformer as T
from .layers import rms_norm

init_params = T.init_params
param_specs = T.param_specs
init_cache = T.init_cache
cache_specs = T.cache_specs


def forward(params, cfg: ModelConfig, batch, *, compute_dtype=jnp.bfloat16,
            remat: str = "full"):
    """batch = {"image_embeds": (b, p, d), "tokens": (b, s)} -> text logits."""
    return T.forward(params, cfg, batch["tokens"],
                     compute_dtype=compute_dtype, remat=remat,
                     prefix_embeds=batch["image_embeds"])


def prefill(params, cfg: ModelConfig, batch, max_len,
            *, compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16):
    """Prefill over [image prefix + text tokens]; returns (logits, cache).

    Cache positions [0, p) hold the image prefix keys/values.
    """
    img = batch["image_embeds"]
    tokens = batch["tokens"]
    b, p = img.shape[:2]
    s = tokens.shape[1]
    cache = T.init_cache(cfg, b, max_len, cache_dtype)

    h_img = img.astype(compute_dtype)
    h_txt = L.embed_tokens(params["embed"], tokens).astype(compute_dtype)
    h = jnp.concatenate([h_img, h_txt], axis=1)
    positions = jnp.arange(p + s)

    def body(x, scanned):
        lp, lc = scanned
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        hh, nc = L.attention(
            rms_norm(x, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
            positions=positions, prefix_len=p, cache=lc,
            cache_pos=jnp.int32(0))
        x = x + hh
        x = x + L.mlp(rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
        return x, nc

    h, cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = rms_norm(h[:, p:], params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return L.lm_logits(params["embed"], h.astype(jnp.float32)), cache


def decode_step(params, cfg: ModelConfig, tokens, cache, pos,
                *, compute_dtype=jnp.bfloat16):
    """pos counts [prefix + generated] positions (cache write offset)."""
    return T.decode_step(params, cfg, tokens, cache, pos,
                         compute_dtype=compute_dtype)
