"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention+MLP block
applied every ``shared_attn_every`` SSM blocks (weights reused each time,
but each application keeps its own KV cache).

Execution plan: n_layers = n_groups × shared_attn_every; scan over groups,
each group = inner scan of `shared_attn_every` mamba blocks + one application
of the shared transformer block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import ssm
from .layers import rms_norm


def _groups(cfg: ModelConfig):
    g = cfg.shared_attn_every
    assert g and cfg.n_layers % g == 0, "n_layers must divide shared_attn_every"
    return cfg.n_layers // g, g


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ke, kl, ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: ssm.init_mamba_block(k, cfg, dtype))(layer_keys)
    k1, k2 = jax.random.split(ks)
    shared = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k2, cfg, dtype, gated=True),
    }
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "mamba": stacked,
        "shared_attn": shared,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }


def param_specs(cfg: ModelConfig):
    leaf = lambda s: isinstance(s, tuple)
    stack = jax.tree.map(lambda s: ("layers",) + tuple(s),
                         ssm.mamba_block_specs(cfg), is_leaf=leaf)
    return {
        "embed": L.embed_specs(cfg),
        "mamba": stack,
        "shared_attn": {
            "ln1": ("embed",),
            "attn": L.attention_specs(cfg),
            "ln2": ("embed",),
            "mlp": L.mlp_specs(gated=True),
        },
        "ln_f": ("embed",),
    }


def _shared_block(cfg, x, sp, *, positions, cache=None, cache_pos=None):
    h, nc = L.attention(rms_norm(x, sp["ln1"], cfg.norm_eps), sp["attn"], cfg,
                        positions=positions, cache=cache, cache_pos=cache_pos)
    x = x + h
    x = x + L.mlp(rms_norm(x, sp["ln2"], cfg.norm_eps), sp["mlp"])
    return x, nc


def forward(params, cfg: ModelConfig, tokens, *, compute_dtype=jnp.bfloat16,
            remat: str = "full", prefix_embeds=None):
    n_groups, per = _groups(cfg)
    h = L.embed_tokens(params["embed"], tokens).astype(compute_dtype)
    positions = jnp.arange(h.shape[1])
    shared = jax.tree.map(lambda a: a.astype(compute_dtype),
                          params["shared_attn"])
    grouped = jax.tree.map(
        lambda a: a.reshape(n_groups, per, *a.shape[1:]), params["mamba"])

    def group_body(x, glp):
        def inner(xx, lp):
            lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
            return ssm.mamba_forward(lp, cfg, xx), None
        x, _ = jax.lax.scan(inner, x, glp)
        x, _ = _shared_block(cfg, x, shared, positions=positions)
        return x, None

    if remat in ("full", "dots"):
        group_body = jax.checkpoint(group_body)
    h, _ = jax.lax.scan(group_body, h, grouped)
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return L.lm_logits(params["embed"], h.astype(jnp.float32))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch, max_len, dtype=jnp.bfloat16):
    n_groups, per = _groups(cfg)
    m_one = ssm.init_mamba_cache(cfg, batch)
    a_one = L.init_attention_cache(cfg, batch, max_len, dtype)

    def rep(a, *lead):
        return jnp.broadcast_to(a[(None,) * len(lead)], tuple(lead) + a.shape)

    return {
        "mamba": jax.tree.map(lambda a: rep(a, n_groups, per), m_one),
        "attn": jax.tree.map(lambda a: rep(a, n_groups), a_one),
    }


def cache_specs(cfg: ModelConfig):
    leaf = lambda s: isinstance(s, tuple)
    return {
        "mamba": jax.tree.map(lambda s: ("layers", None) + tuple(s),
                              ssm.mamba_cache_specs(cfg), is_leaf=leaf),
        "attn": jax.tree.map(lambda s: ("layers",) + tuple(s),
                             L.attention_cache_specs(cfg), is_leaf=leaf),
    }


def _serve(params, cfg, h, cache, pos, compute_dtype, *, prefill_mode):
    n_groups, per = _groups(cfg)
    positions = pos + jnp.arange(h.shape[1])
    shared = jax.tree.map(lambda a: a.astype(compute_dtype),
                          params["shared_attn"])
    grouped = jax.tree.map(
        lambda a: a.reshape(n_groups, per, *a.shape[1:]), params["mamba"])

    def group_body(x, scanned):
        glp, m_cache, a_cache = scanned

        def inner(xx, sc):
            lp, lc = sc
            lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
            if prefill_mode:
                xx, nc = ssm.mamba_forward(lp, cfg, xx, return_cache=True)
            else:
                xx, nc = ssm.mamba_decode_step(lp, cfg, xx, lc)
            return xx, nc

        x, m_nc = jax.lax.scan(inner, x, (glp, m_cache))
        x, a_nc = _shared_block(cfg, x, shared, positions=positions,
                                cache=a_cache, cache_pos=pos)
        return x, (m_nc, a_nc)

    h, (m_nc, a_nc) = jax.lax.scan(group_body, h,
                                   (grouped, cache["mamba"], cache["attn"]))
    return h, {"mamba": m_nc, "attn": a_nc}


def decode_step(params, cfg: ModelConfig, tokens, cache, pos,
                *, compute_dtype=jnp.bfloat16):
    h = L.embed_tokens(params["embed"], tokens).astype(compute_dtype)
    h, cache = _serve(params, cfg, h, cache, pos, compute_dtype,
                      prefill_mode=False)
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return L.lm_logits(params["embed"], h.astype(jnp.float32)), cache


def prefill(params, cfg: ModelConfig, tokens, max_len,
            *, compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16):
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    h = L.embed_tokens(params["embed"], tokens).astype(compute_dtype)
    h, cache = _serve(params, cfg, h, cache, jnp.int32(0), compute_dtype,
                      prefill_mode=True)
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return L.lm_logits(params["embed"], h.astype(jnp.float32)), cache
