"""Mamba2 (SSD — state-space duality) blocks, pure JAX.

Training/prefill uses the *chunked SSD* algorithm (Dao & Gu 2024): the
sequence is split into Q-length chunks; within-chunk terms become dense
(q, q) matmuls (MXU-friendly — this is the TPU adaptation of the SSD scan)
and cross-chunk terms are a tiny associative scan over chunk states.
Decode carries (state (b, h, p, n), conv buffer) — O(1) per token.

Shapes: b=batch s=seq h=ssm heads p=head_dim n=d_state g=groups(1) q=chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SSMConfig
from ..distributed.sharding import constrain
from .layers import dense_init, rms_norm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state          # x, B, C go through the conv
    return s, d_inner, n_heads, conv_dim


def init_mamba_block(key, cfg: ModelConfig, dtype=jnp.float32):
    s, d_inner, h, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * s.d_state + h    # z, x, B, C, dt
    dt = jnp.exp(
        jax.random.uniform(ks[2], (h,), jnp.float32)
        * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))     # inverse softplus
    return {
        "ln": jnp.ones((cfg.d_model,), dtype),
        "in_proj": dense_init(ks[0], (cfg.d_model, in_dim), cfg.d_model, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": dt_bias.astype(dtype),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)).astype(dtype),
        "d_skip": jnp.ones((h,), dtype),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[3], (d_inner, cfg.d_model), d_inner, dtype),
    }


def mamba_block_specs(cfg: ModelConfig):
    return {
        "ln": ("embed",),
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "dt_bias": ("ssm_heads",),
        "a_log": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


def _split_proj(cfg, zxbcdt):
    s, d_inner, h, _ = _dims(cfg)
    z, x, b_ssm, c_ssm, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + s.d_state,
         2 * d_inner + 2 * s.d_state],
        axis=-1,
    )
    return z, x, b_ssm, c_ssm, dt


def _segsum(x):
    """x (..., q, h) -> (..., h, q, q) lower-triangular pairwise sums
    seg[i, j] = sum_{j < t <= i} x_t   (i >= j), -inf above the diagonal."""
    q = x.shape[-2]
    cs = jnp.cumsum(x, axis=-2)                          # (..., q, h)
    cs = jnp.moveaxis(cs, -1, -2)                        # (..., h, q)
    diff = cs[..., :, None] - cs[..., None, :]           # (..., h, q, q)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b_ssm, c_ssm, *, chunk):
    """Chunked SSD. x (b,s,h,p), dt (b,s,h), a (h,)<0 via -exp(a_log),
    b_ssm/c_ssm (b,s,n). Returns y (b,s,h,p) and final state (b,h,p,n)."""
    bsz, s, h, p = x.shape
    n = b_ssm.shape[-1]
    q = min(chunk, s)
    s_orig = s
    if s % q:
        # pad with zero-input steps: dt=0 gives unit decay and no state
        # contribution, so outputs/states for real positions are unchanged
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ssm = jnp.pad(b_ssm, ((0, 0), (0, pad), (0, 0)))
        c_ssm = jnp.pad(c_ssm, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // q

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = b_ssm.reshape(bsz, nc, q, n)
    cc = c_ssm.reshape(bsz, nc, q, n)

    da = dtc * a                                          # (b,c,q,h)
    xdt = xc * dtc[..., None]                             # (b,c,q,h,p)

    # --- diagonal (within-chunk) term: dense (q, q) matmuls on the MXU
    l_mat = jnp.exp(_segsum(da))                          # (b,c,h,q,q)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)        # (b,c,q,q)
    m = scores[:, :, None] * l_mat                        # (b,c,h,q,q)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", m, xdt)

    # --- chunk summary states: S_c = sum_j exp(cs_end - cs_j) B_j x_j^T
    cs = jnp.cumsum(da, axis=2)                           # (b,c,q,h)
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)            # (b,c,q,h)
    s_chunk = jnp.einsum("bcqn,bcqhp->bchpn", bc, xdt * decay_end[..., None])

    # --- inter-chunk recurrence (associative scan over nc chunks)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                # (b,c,h)

    def combine(left, right):
        d1, s1 = left
        d2, s2 = right
        return d1 * d2, s1 * d2[..., None, None] + s2

    dec_scan, st_scan = jax.lax.associative_scan(
        combine, (jnp.moveaxis(chunk_decay, 1, 0),
                  jnp.moveaxis(s_chunk, 1, 0)), axis=0)
    # state at START of chunk c = scanned state up to c-1 (shift by one)
    st_incl = jnp.moveaxis(st_scan, 0, 1)                 # (b,c,h,p,n) inclusive
    h0 = jnp.zeros_like(st_incl[:, :1])
    h_start = jnp.concatenate([h0, st_incl[:, :-1]], axis=1)

    # --- off-diagonal term: y_off[i] = (C_i · H_start) * exp(cs_i)
    y_off = jnp.einsum("bcqn,bchpn->bcqhp", cc, h_start) \
        * jnp.exp(cs)[..., None]

    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    final_state = st_incl[:, -1]                          # (b,h,p,n)
    return y, final_state


def mamba_forward(params, cfg: ModelConfig, u, *, chunk=None,
                  return_cache=False):
    """Full-sequence Mamba2 block. u (b,s,d_model) -> (b,s,d_model).

    ``return_cache`` also returns the decode cache (conv tail + final state)
    so prefill can hand off to the recurrent decode path.
    """
    s_cfg, d_inner, h, conv_dim = _dims(cfg)
    q = chunk or s_cfg.chunk
    res = u
    u = rms_norm(u, params["ln"], cfg.norm_eps)
    zxbcdt = u @ params["in_proj"]
    z, x, b_ssm, c_ssm, dt = _split_proj(cfg, zxbcdt)

    # depthwise causal conv over (x, B, C)
    xbc_pre = jnp.concatenate([x, b_ssm, c_ssm], axis=-1)  # (b,s,conv_dim)
    w = params["conv_w"]                                   # (d_conv, conv_dim)
    pad = w.shape[0] - 1
    xbc_p = jnp.pad(xbc_pre, ((0, 0), (pad, 0), (0, 0)))
    conv = sum(
        xbc_p[:, i : i + xbc_pre.shape[1]] * w[i][None, None]
        for i in range(w.shape[0])
    ) + params["conv_b"]
    xbc = jax.nn.silu(conv)
    x, b_ssm, c_ssm = jnp.split(xbc, [d_inner, d_inner + s_cfg.d_state],
                                axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = x.reshape(*x.shape[:2], h, s_cfg.head_dim)
    xh = constrain(xh, "batch", "seq", "ssm_heads", None)
    y, final_state = ssd_chunked(
        xh.astype(jnp.float32), dt, a,
        b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32), chunk=q)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    out = res + constrain(out, "batch", "seq", "embed")
    if return_cache:
        cache = {"conv": xbc_pre[:, -(s_cfg.d_conv - 1):].astype(jnp.float32),
                 "state": final_state}
        return out, cache
    return out


# ---------------------------------------------------------------------------
# decode (recurrent, O(1) per token)
# ---------------------------------------------------------------------------


def init_mamba_cache(cfg: ModelConfig, batch, dtype=jnp.float32):
    s, d_inner, h, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, h, s.head_dim, s.d_state), dtype),
    }


def mamba_cache_specs(cfg: ModelConfig):
    return {
        "conv": ("batch", None, "ssm_inner"),
        "state": ("batch", "ssm_heads", None, None),
    }


def mamba_decode_step(params, cfg: ModelConfig, u, cache):
    """u (b, 1, d_model); cache {conv (b, k-1, conv_dim), state (b,h,p,n)}."""
    s_cfg, d_inner, h, conv_dim = _dims(cfg)
    res = u
    un = rms_norm(u, params["ln"], cfg.norm_eps)
    zxbcdt = un @ params["in_proj"]
    z, x, b_ssm, c_ssm, dt = _split_proj(cfg, zxbcdt)

    xbc_new = jnp.concatenate([x, b_ssm, c_ssm], axis=-1)[:, 0]  # (b, conv_dim)
    hist = jnp.concatenate([cache["conv"],
                            xbc_new[:, None].astype(cache["conv"].dtype)],
                           axis=1)                         # (b, k, conv_dim)
    w = params["conv_w"]
    conv = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                      w.astype(jnp.float32)) + params["conv_b"]
    xbc = jax.nn.silu(conv)
    x1, b1, c1 = jnp.split(xbc, [d_inner, d_inner + s_cfg.d_state], axis=-1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))  # (b,h)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))               # (h,)
    da = jnp.exp(dt1 * a)                                           # (b,h)
    xh = x1.reshape(-1, h, s_cfg.head_dim).astype(jnp.float32)      # (b,h,p)
    # state' = exp(dt a) state + dt * x ⊗ B
    new_state = cache["state"] * da[..., None, None] \
        + jnp.einsum("bhp,bn,bh->bhpn", xh, b1.astype(jnp.float32), dt1)
    y = jnp.einsum("bhpn,bn->bhp", new_state, c1.astype(jnp.float32))
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, 1, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    new_cache = {"conv": hist[:, 1:], "state": new_state}
    return res + out, new_cache


# ---------------------------------------------------------------------------
# full mamba2 LM (mamba2-780m)
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    from .layers import init_embed
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_mamba_block(k, cfg, dtype))(layer_keys)
    return {
        "embed": init_embed(ke, cfg, dtype),
        "layers": stacked,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }


def param_specs(cfg: ModelConfig):
    from .layers import embed_specs
    stack = jax.tree.map(lambda s: ("layers",) + tuple(s),
                         mamba_block_specs(cfg),
                         is_leaf=lambda s: isinstance(s, tuple))
    return {"embed": embed_specs(cfg), "layers": stack, "ln_f": ("embed",)}


def forward(params, cfg: ModelConfig, tokens, *, compute_dtype=jnp.bfloat16,
            remat: str = "full", prefix_embeds=None):
    from .layers import embed_tokens, lm_logits
    h = embed_tokens(params["embed"], tokens).astype(compute_dtype)

    def body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        return mamba_forward(lp, cfg, x), None

    if remat in ("full", "dots"):
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return lm_logits(params["embed"], h.astype(jnp.float32))


def init_cache(cfg: ModelConfig, batch, max_len, dtype=jnp.float32):
    del max_len  # O(1) state
    one = init_mamba_cache(cfg, batch, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)


def cache_specs(cfg: ModelConfig):
    return jax.tree.map(lambda s: ("layers",) + tuple(s),
                        mamba_cache_specs(cfg),
                        is_leaf=lambda s: isinstance(s, tuple))


def decode_step(params, cfg: ModelConfig, tokens, cache, pos,
                *, compute_dtype=jnp.bfloat16):
    from .layers import embed_tokens, lm_logits
    del pos  # state is position-free
    h = embed_tokens(params["embed"], tokens).astype(compute_dtype)

    def body(x, scanned):
        lp, lc = scanned
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        x, nc = mamba_decode_step(lp, cfg, x, lc)
        return x, nc

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return lm_logits(params["embed"], h.astype(jnp.float32)), new_cache


def prefill(params, cfg: ModelConfig, tokens, max_len,
            *, compute_dtype=jnp.bfloat16, cache_dtype=jnp.float32):
    """Full-sequence forward returning logits + per-layer decode cache."""
    del max_len  # O(1) state
    from .layers import embed_tokens, lm_logits
    h = embed_tokens(params["embed"], tokens).astype(compute_dtype)

    def body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(compute_dtype), lp)
        x, cache = mamba_forward(lp, cfg, x, return_cache=True)
        return x, jax.tree.map(lambda a: a.astype(cache_dtype), cache)

    h, cache = jax.lax.scan(body, h, params["layers"])
    h = rms_norm(h, params["ln_f"].astype(compute_dtype), cfg.norm_eps)
    return lm_logits(params["embed"], h.astype(jnp.float32)), cache
