"""Shared transformer building blocks (pure JAX, param pytrees = nested dicts).

Conventions:
  - einsum letters: b=batch s/t=seq h=heads k=kv-heads d=head_dim e=embed
    f=ff v=vocab
  - every init fn has a sibling ``*_specs`` returning the same pytree of
    LOGICAL axis tuples (resolved to PartitionSpecs by distributed.sharding).
  - attention supports: causal, sliding-window (SWA), prefix-LM (bidirectional
    prefix), cross-attention, and KV-cache decode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / jnp.sqrt(jnp.asarray(in_axis_size, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_table(positions, head_dim, theta):
    """positions (…,) int -> (…, head_dim/2) cos/sin tables (f32)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (b, s, h, d) with cos/sin (s, d/2) or (b, s, d/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:                       # (s, half) -> broadcast b, h
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:                                   # (b, s, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), d, dtype),
        "wk": dense_init(ks[1], (d, kv * hd), d, dtype),
        "wv": dense_init(ks[2], (d, kv * hd), d, dtype),
        "wo": dense_init(ks[3], (h * hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def attention_specs(cfg: ModelConfig):
    s = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        s["bq"] = ("heads",)
        s["bk"] = ("kv_heads",)
        s["bv"] = ("kv_heads",)
    return s


def _build_mask(q_len, kv_len, *, causal, window, prefix_len, q_offset):
    """Additive mask (q_len, kv_len) in f32 (0 or -inf)."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    ok = jnp.ones((q_len, kv_len), bool)
    if causal:
        ok = kj <= qi
        if window:
            ok &= kj > qi - window
        if prefix_len:
            ok |= kj < prefix_len          # bidirectional over the prefix
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def attention(
    x,
    p,
    cfg: ModelConfig,
    *,
    positions=None,            # (s,) int32 positions of x in the sequence
    causal=True,
    prefix_len=0,
    x_kv=None,                 # cross-attention source (b, s_kv, e)
    cache=None,                # dict(k, v) (b, kv, S_max, d) for decode
    cache_pos=None,            # scalar int32 — write offset in the cache
    rope=True,
):
    """Returns (out (b,s,e), new_cache)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    src = x if x_kv is None else x_kv
    s_kv = src.shape[1]

    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s_kv, kv, hd)
    v = v.reshape(b, s_kv, kv, hd)

    if positions is None:
        positions = jnp.arange(s)
    if rope and x_kv is None:
        cos_q, sin_q = rope_table(positions, hd, cfg.rope_theta)
        # keep q/k in the compute dtype: RoPE's f32 tables would otherwise
        # promote the attention einsums (and the whole KV cache!) to f32
        q = apply_rope(q, cos_q, sin_q).astype(v.dtype)
        k = apply_rope(k, cos_q, sin_q).astype(v.dtype)

    q = constrain(q, "batch", "seq", "heads_act", None)
    k = constrain(k, "batch", "seq", "kv_heads_act", None)
    v = constrain(v, "batch", "seq", "kv_heads_act", None)

    from ..distributed.sharding import naive_mode

    q_offset = 0
    if (cache is not None and s == 1 and x_kv is None and not naive_mode()):
        flash = _maybe_flash_decode(q, k, v, cache, cache_pos, cfg, b, h, kv,
                                    hd)
        if flash is not None:
            out, cache = flash
            out = out.reshape(b, s, h * hd) @ p["wo"]
            return constrain(out, "batch", "seq", "embed"), cache
    if cache is not None:
        # decode / incremental: append k,v at cache_pos, attend over cache
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, cache_pos, 0, 0))
        cache = {"k": ck, "v": cv}
        k, v = ck, cv
        s_kv = k.shape[1]
        q_offset = cache_pos

    rep = h // kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if naive_mode() and rep > 1:
        # paper-naive GQA: materialize repeated K/V (baseline for §Perf H1)
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        kv, rep = h, 1

    # long-sequence full forward: blockwise over query chunks so the (s, s)
    # score matrix is never materialized (flash-attention-style tiling; the
    # TPU-memory-realistic path for the 32k prefill cells)
    if (cache is None and x_kv is None and s == s_kv and s > _BLOCKWISE_MIN
            and s % _BLOCK_Q == 0):
        out = _blockwise_causal_attention(
            q, k, v, cfg, scale, prefix_len=prefix_len)
        out = constrain(out, "batch", "seq", "heads_act", None)
        out = out.reshape(b, s, h * hd) @ p["wo"]
        return constrain(out, "batch", "seq", "embed"), cache

    # grouped-query attention WITHOUT materializing repeated K/V (opt H1):
    # q (b,s,h,hd) -> (b,s,kv,rep,hd); contract each kv group directly.
    qg = q.reshape(b, s, kv, rep, hd)
    logits = jnp.einsum("bskrd,btkd->bkrst", qg, k).astype(jnp.float32) * scale
    logits = logits.reshape(b, h, s, s_kv)

    if cache is not None:
        # mask: key position must be <= q_offset + row and already written
        qi = q_offset + jnp.arange(s)[:, None]
        kj = jnp.arange(s_kv)[None, :]
        ok = kj <= qi
        if cfg.sliding_window:
            ok &= kj > qi - cfg.sliding_window
        if prefix_len:
            ok |= (kj < prefix_len) & (qi < prefix_len)  # bidirectional prefix
        mask = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)
    elif x_kv is not None:
        mask = jnp.zeros((s, s_kv), jnp.float32)          # full cross-attn
    else:
        mask = _build_mask(s, s_kv, causal=causal,
                           window=cfg.sliding_window, prefix_len=prefix_len,
                           q_offset=0)
    logits = logits + mask[None, None]

    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    probs = probs.reshape(b, kv, rep, s, s_kv)
    out = jnp.einsum("bkrst,btkd->bskrd", probs, v)
    out = out.reshape(b, s, h, hd)
    out = constrain(out, "batch", "seq", "heads_act", None)
    out = out.reshape(b, s, h * hd) @ p["wo"]
    return constrain(out, "batch", "seq", "embed"), cache


_BLOCKWISE_MIN = 8192   # use blockwise attention above this sequence length
_BLOCK_Q = 1024


def _blockwise_causal_attention(q, k, v, cfg, scale, *, prefix_len=0):
    """Query-chunked causal attention: peak memory O(block_q * s) per head.

    Scans over query blocks; each block computes its (block_q, s) scores,
    masks (causal/SWA/prefix), softmaxes and contracts with V. K/V stay in
    grouped (kv-head) layout — never repeated (opt H1).
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    nq = s // _BLOCK_Q
    qb = q.reshape(b, nq, _BLOCK_Q, kv, rep, hd).transpose(1, 0, 2, 3, 4, 5)

    kj = jnp.arange(s)[None, :]

    def block(carry, args):
        qi_block, q_off = args                        # (b, Q, kv, rep, hd)
        logits = jnp.einsum("bskrd,btkd->bkrst", qi_block, k)
        logits = logits.astype(jnp.float32) * scale
        qi = q_off + jnp.arange(_BLOCK_Q)[:, None]
        ok = kj <= qi
        if cfg.sliding_window:
            ok &= kj > qi - cfg.sliding_window
        if prefix_len:
            ok |= (kj < prefix_len) & (qi < prefix_len)
        logits = logits + jnp.where(ok, 0.0, -jnp.inf)[None, None, None]
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkrst,btkd->bskrd", probs, v)
        return carry, out

    offs = jnp.arange(nq) * _BLOCK_Q
    _, outs = jax.lax.scan(block, None, (qb, offs))
    # (nq, b, Q, kv, rep, hd) -> (b, s, h, hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd)


def _maybe_flash_decode(q, k_new, v_new, cache, pos, cfg, b, h, kv, hd):
    """Flash-decoding for a SEQ-SHARDED KV cache (opt H3, shard_map).

    When the sharding rules map "cache_seq" to mesh axes (MQA/GQA archs whose
    kv-head count cannot shard over "model"), the naive GSPMD lowering of the
    cache update rewrites the full cache through selects every step. This
    manual kernel instead:
      1. writes the new K/V into the single owning shard (one-slot DUS;
         non-owners rewrite their existing slot),
      2. computes a LOCAL partial softmax (m, l, o) over its cache shard,
      3. combines across shards with tiny psums (flash-attention algebra).
    Per-step HBM traffic: read each cache shard once. Collectives: O(b·h) + o.
    Returns None when the layout doesn't apply (falls back to dense path).
    """
    from ..distributed.sharding import (
        current_mesh, current_rules, logical_to_spec)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = current_mesh()
    rules = current_rules()
    if mesh is None or rules is None or not rules.get("cache_seq"):
        return None

    cache_spec = logical_to_spec(("batch", "cache_seq", None, None))
    seq_axes = cache_spec[1]
    if seq_axes is None:
        return None
    seq_axes = (seq_axes,) if isinstance(seq_axes, str) else tuple(seq_axes)
    q_spec = logical_to_spec(("batch", None, None, None))

    rep = h // kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    window = cfg.sliding_window

    def fn(q_l, kn_l, vn_l, ck_l, cv_l, pos):
        b_l, s_loc = ck_l.shape[0], ck_l.shape[1]
        idx = jax.lax.axis_index(seq_axes)
        start = (idx * s_loc).astype(jnp.int32)
        local_pos = jnp.clip(pos - start, 0, s_loc - 1)
        is_owner = (pos >= start) & (pos < start + s_loc)

        def write(buf, new):
            old = jax.lax.dynamic_slice(
                buf, (0, local_pos, 0, 0), (b_l, 1, kv, hd))
            val = jnp.where(is_owner, new.astype(buf.dtype), old)
            return jax.lax.dynamic_update_slice(buf, val,
                                                (0, local_pos, 0, 0))

        ck_l = write(ck_l, kn_l)
        cv_l = write(cv_l, vn_l)

        qg = q_l.reshape(b_l, 1, kv, rep, hd).astype(ck_l.dtype)
        logits = jnp.einsum("bskrd,btkd->bkrst", qg, ck_l,
                            preferred_element_type=jnp.float32)
        logits = logits * scale                          # (b, kv, rep, 1, t)
        ids = start + jnp.arange(s_loc)
        ok = ids <= pos
        if window:
            ok &= ids > pos - window
        logits = jnp.where(ok[None, None, None, None, :], logits, -jnp.inf)

        m_loc = jnp.max(logits, axis=-1)                 # (b, kv, rep, 1)
        m = jax.lax.pmax(m_loc, seq_axes)
        p = jnp.exp(logits - m[..., None])
        p = jnp.where(jnp.isfinite(logits), p, 0.0)
        l = jax.lax.psum(jnp.sum(p, axis=-1), seq_axes)  # (b, kv, rep, 1)
        o_loc = jnp.einsum("bkrst,btkd->bskrd", p.astype(cv_l.dtype), cv_l)
        o = jax.lax.psum(o_loc.astype(jnp.float32), seq_axes)
        o = o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return o.reshape(b_l, 1, h, hd).astype(cv_l.dtype), ck_l, cv_l

    out, ck, cv = shard_map(
        fn, mesh=mesh,
        in_specs=(q_spec, q_spec, q_spec, cache_spec, cache_spec, P()),
        out_specs=(q_spec, cache_spec, cache_spec),
        check_rep=False,
    )(q, k_new, v_new, cache["k"], cache["v"], pos)
    return out, {"k": ck, "v": cv}


def init_attention_cache(cfg: ModelConfig, batch, max_len, dtype=jnp.bfloat16):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def attention_cache_specs(cfg: ModelConfig):
    return {
        "k": ("batch", "cache_seq", "kv_heads_act", None),
        "v": ("batch", "cache_seq", "kv_heads_act", None),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, dtype=jnp.float32, d_ff=None, gated=True):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if gated:
        return {
            "wg": dense_init(ks[0], (d, f), d, dtype),
            "wu": dense_init(ks[1], (d, f), d, dtype),
            "wd": dense_init(ks[2], (f, d), f, dtype),
        }
    return {
        "wu": dense_init(ks[0], (d, f), d, dtype),
        "wd": dense_init(ks[1], (f, d), f, dtype),
    }


def mlp_specs(gated=True):
    if gated:
        return {"wg": ("embed", "mlp"), "wu": ("embed", "mlp"),
                "wd": ("mlp", "embed")}
    return {"wu": ("embed", "mlp"), "wd": ("mlp", "embed")}


def mlp(x, p):
    if "wg" in p:
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wu"])
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(h @ p["wd"], "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# embeddings / lm head
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig, dtype=jnp.float32):
    p = {"tok": embed_init(key, (cfg.vocab_padded, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_padded),
            cfg.d_model, dtype)
    return p


def embed_specs(cfg: ModelConfig):
    s = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        s["head"] = ("embed", "vocab")
    return s


def embed_tokens(p, tokens):
    return constrain(p["tok"][tokens], "batch", "seq", "embed")


def lm_logits(p, x):
    w = p["head"] if "head" in p else p["tok"].T
    return constrain(x @ w, "batch", "seq", "vocab")
