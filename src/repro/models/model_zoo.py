"""Uniform model API over all families + input-spec builders for the dry-run.

ModelAPI:
  init_params(key, cfg, dtype)        -> param pytree
  param_specs(cfg)                    -> pytree of logical-axis tuples
  forward(params, cfg, batch, **kw)   -> logits (b, s, v)
  init_cache(cfg, batch, max_len)     -> decode cache
  cache_specs(cfg)                    -> cache logical axes
  decode_step(params, cfg, tokens, cache, pos, extras, **kw) -> (logits, cache)
  prefill(params, cfg, batch, max_len, **kw) -> (logits, cache[, extras])

Batch layouts (all int32 tokens/labels):
  dense/ssm/hybrid/moe : {tokens, labels}
  encdec               : {src_embeds (b,s,d) bf16, tokens, labels}
  vlm                  : {image_embeds (b,p,d) bf16, tokens, labels}
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCell
from . import encdec, hybrid, moe, ssm, transformer, vlm


@dataclass(frozen=True)
class ModelAPI:
    family: str
    init_params: Callable
    param_specs: Callable
    forward: Callable                  # (params, cfg, batch, **kw) -> logits
    init_cache: Optional[Callable]
    cache_specs: Optional[Callable]
    decode_step: Optional[Callable]    # (params,cfg,tokens,cache,pos,extras)
    prefill: Optional[Callable]


def _dense_forward(mod):
    def fwd(params, cfg, batch, **kw):
        return mod.forward(params, cfg, batch["tokens"], **kw)
    return fwd


def _dense_decode(mod):
    def step(params, cfg, tokens, cache, pos, extras=None, **kw):
        return mod.decode_step(params, cfg, tokens, cache, pos, **kw)
    return step


def _dense_prefill(mod):
    def pre(params, cfg, batch, max_len, **kw):
        return mod.prefill(params, cfg, batch["tokens"], max_len, **kw)
    return pre


def _encdec_decode(params, cfg, tokens, cache, pos, extras=None, **kw):
    return encdec.decode_step(params, cfg, tokens, cache, pos,
                              extras["enc_out"], **kw)


def _vlm_prefill(params, cfg, batch, max_len, **kw):
    return vlm.prefill(params, cfg, batch, max_len, **kw)


_FAMILIES: dict[str, ModelAPI] = {
    "dense": ModelAPI(
        "dense", transformer.init_params, transformer.param_specs,
        _dense_forward(transformer), transformer.init_cache,
        transformer.cache_specs, _dense_decode(transformer),
        _dense_prefill(transformer)),
    "ssm": ModelAPI(
        "ssm", ssm.init_params, ssm.param_specs,
        _dense_forward(ssm), ssm.init_cache, ssm.cache_specs,
        _dense_decode(ssm), _dense_prefill(ssm)),
    "hybrid": ModelAPI(
        "hybrid", hybrid.init_params, hybrid.param_specs,
        _dense_forward(hybrid), hybrid.init_cache, hybrid.cache_specs,
        _dense_decode(hybrid), _dense_prefill(hybrid)),
    "moe": ModelAPI(
        "moe", moe.init_params, moe.param_specs,
        _dense_forward(moe), moe.init_cache, moe.cache_specs,
        _dense_decode(moe), _dense_prefill(moe)),
    "encdec": ModelAPI(
        "encdec", encdec.init_params, encdec.param_specs,
        lambda p, c, b, **kw: encdec.forward(p, c, b, **kw),
        encdec.init_cache, encdec.cache_specs, _encdec_decode,
        lambda p, c, b, m, **kw: encdec.prefill(p, c, b, m, **kw)),
    "vlm": ModelAPI(
        "vlm", vlm.init_params, vlm.param_specs,
        lambda p, c, b, **kw: vlm.forward(p, c, b, **kw),
        vlm.init_cache, vlm.cache_specs, _dense_decode(vlm), _vlm_prefill),
}


def get_api(cfg: ModelConfig) -> ModelAPI:
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# abstract input specs (ShapeDtypeStructs — no allocation) per shape cell
# ---------------------------------------------------------------------------


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int):
    sd = jax.ShapeDtypeStruct
    b = {
        "tokens": sd((batch, seq), jnp.int32),
        "labels": sd((batch, seq), jnp.int32),
    }
    if cfg.family == "encdec":
        b["src_embeds"] = sd((batch, seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        b["image_embeds"] = sd((batch, cfg.n_prefix_tokens, cfg.d_model),
                               jnp.bfloat16)
    return b


def decode_inputs_specs(cfg: ModelConfig, batch: int, cache_len: int):
    """Inputs for one decode step at a cache of length cache_len."""
    sd = jax.ShapeDtypeStruct
    api = get_api(cfg)
    cache = jax.eval_shape(
        lambda: api.init_cache(cfg, batch, cache_len, jnp.bfloat16))
    out = {
        "tokens": sd((batch, 1), jnp.int32),
        "cache": cache,
        "pos": sd((), jnp.int32),
    }
    if cfg.family == "encdec":
        out["extras"] = {"enc_out": sd((batch, cache_len, cfg.d_model),
                                       jnp.bfloat16)}
    return out


def make_train_batch(cfg: ModelConfig, batch: int, seq: int, key):
    """Concrete random batch (smoke tests / examples)."""
    kt, ke = jax.random.split(jax.random.key(key) if isinstance(key, int) else key)
    b = {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size,
                                     jnp.int32),
    }
    b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    if cfg.family == "encdec":
        b["src_embeds"] = jax.random.normal(
            ke, (batch, seq, cfg.d_model), jnp.float32) * 0.02
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            ke, (batch, cfg.n_prefix_tokens, cfg.d_model), jnp.float32) * 0.02
    return b
