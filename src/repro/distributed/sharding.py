"""Logical-axis sharding rules (flax-linen-style, dependency-free).

Model code annotates activations/params with *logical* axis names
("batch", "seq", "embed", "heads", "kv_heads", "mlp", "vocab", "experts",
"expert_mlp", "layers", ...). A rules table maps logical names to mesh axes.
Outside a rules context (CPU smoke tests) every constraint is the identity.

The production rules (launch/mesh.py) are Megatron-style:
    batch   -> ("pod", "data")        heads/kv_heads/mlp/vocab/experts -> "model"
with per-cell overrides decided by the launcher (e.g. sequence-parallel KV
cache for long-context decode).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

MeshAxis = Union[None, str, tuple[str, ...]]

_state = threading.local()


def _get() -> Optional[dict[str, MeshAxis]]:
    return getattr(_state, "rules", None)


def set_axis_rules(rules: Optional[Mapping[str, MeshAxis]]) -> None:
    _state.rules = dict(rules) if rules is not None else None


def current_rules() -> Optional[dict[str, MeshAxis]]:
    return _get()


def naive_mode() -> bool:
    """REPRO_NAIVE=1 disables the beyond-baseline optimizations (grouped-QKV
    attention, flash decoding, shard_map EP MoE) so §Perf can measure the
    naive baseline and the optimized version under identical accounting."""
    import os
    return os.environ.get("REPRO_NAIVE", "0") == "1"


def set_active_mesh(mesh) -> None:
    _state.mesh = mesh


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[Mapping[str, MeshAxis]], mesh=None):
    prev = _get()
    prev_mesh = current_mesh()
    set_axis_rules(rules)
    if mesh is not None:
        set_active_mesh(mesh)
    try:
        yield
    finally:
        set_axis_rules(prev)
        set_active_mesh(prev_mesh)


def logical_to_spec(names: Sequence[Optional[str]]) -> P:
    """Resolve a tuple of logical axis names to a PartitionSpec."""
    rules = _get() or {}
    resolved = []
    used: set = set()

    def dedup(axis):
        # a mesh axis may appear at most once in a PartitionSpec
        if axis is None:
            return None
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        keep = tuple(a for a in axes if a not in used)
        used.update(keep)
        if not keep:
            return None
        return keep[0] if len(keep) == 1 else keep

    for n in names:
        resolved.append(dedup(rules.get(n)) if n is not None else None)
    return P(*resolved)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical names; identity without rules."""
    if _get() is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, logical_to_spec(names))
    except (ValueError, RuntimeError):
        # no mesh context (e.g. abstract tracing without mesh) — best effort
        return x
