from .sharding import (
    axis_rules,
    constrain,
    current_rules,
    logical_to_spec,
    set_axis_rules,
)


def shard_points(x, mesh, shard_axes="data"):
    """Row-shard (n, m) host points on the mesh — the GPIC data front door
    (re-exported from core.distributed; lazy so importing the logical-axis
    rules never pulls in the clustering pipeline)."""
    from ..core.distributed import shard_points as _sp
    return _sp(x, mesh, shard_axes)


__all__ = [
    "axis_rules",
    "constrain",
    "current_rules",
    "logical_to_spec",
    "set_axis_rules",
    "shard_points",
]
