from .sharding import (
    axis_rules,
    constrain,
    current_rules,
    logical_to_spec,
    set_axis_rules,
)

__all__ = [
    "axis_rules",
    "constrain",
    "current_rules",
    "logical_to_spec",
    "set_axis_rules",
]
