"""Paper-faithful Power Iteration Clustering (PIC) — Algorithm 1 of GPIC.

This module is the *reference* implementation: explicit W = D^-1 A, the
truncated power iteration with the paper's acceleration-based stopping rule,
then k-means on the 1-D embedding.

Two variants:
  - ``pic_reference``: pure-jnp, jit-compiled (the correctness oracle).
  - ``pic_serial_numpy``: deliberately un-fused row-loop numpy implementation
    standing in for the paper's serial MATLAB baseline (used by the Table-2
    benchmark to measure speedup structure).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .affinity import (
    AffinityKind,
    AffinitySpec,
    affinity_matrix,
    as_affinity_spec,
)
from .health import HealthReport, count_bad_rows
from .kmeans import kmeans
from .power import (
    batched_power_iteration,
    init_power_vectors,
    run_power_embedding,
    standardize_columns,
)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PICResult:
    labels: jax.Array      # (n,) int32 cluster assignment
    embedding: jax.Array   # (n,) final power-iteration vector v_t (column 0)
    n_iter: jax.Array      # iterations actually executed (column 0)
    converged: jax.Array   # bool — stopped by the epsilon rule (vs max_iter)
    embeddings: jax.Array      # the (n, c) matrix k-means clustered: the
    #   (n, r) engine block for 'pic'/'orthogonal', the (n, r·S) snapshot
    #   concatenation for 'ensemble' — see ``embedding_mode``
    n_iter_cols: jax.Array     # (r,) int32 per-column iteration counts
    converged_cols: jax.Array  # (r,) bool per-column convergence flags
    #: which embedding mode ('pic' | 'orthogonal' | 'ensemble') produced
    #: ``embeddings`` — static metadata, not a traced leaf
    embedding_mode: str = field(metadata=dict(static=True), default="pic")
    #: per-run diagnostics (core/health.py, DESIGN.md §12): per-column
    #: COL_* status codes, isolated-row count, component probe results.
    #: None only for hand-built results that skipped the engine.
    health: Optional[HealthReport] = None


def make_pic_result(labels, v, t_cols, done, *, embedding="pic",
                    embeddings=None, health=None) -> PICResult:
    """Assemble a PICResult from the engine outputs: labels (n,), the final
    (n, r) state, and the per-column (r,) iteration counts / flags. Column 0
    (the paper's degree-seeded vector) backs the scalar back-compat fields;
    the full state rides along so multi-vector callers stop re-deriving it.

    ``embedding`` records which embedding mode produced the clustered
    matrix; ``embeddings`` overrides that matrix when it is wider than the
    engine state (the ensemble concatenation) — ``v`` still supplies the
    column-0 scalars. ``health`` attaches the run's
    :class:`~repro.core.health.HealthReport`.
    """
    return PICResult(
        labels=labels, embedding=v[:, 0], n_iter=t_cols[0], converged=done[0],
        embeddings=v if embeddings is None else embeddings,
        n_iter_cols=t_cols, converged_cols=done, embedding_mode=embedding,
        health=health,
    )


def _power_iterate(
    w_matvec,
    v0: jax.Array,
    eps: float,
    max_iter: int,
):
    """Single-vector truncated power iteration with the paper's stopping rule.

    Stop when || delta_{t+1} - delta_t ||_inf <= eps  where
    delta_{t+1} = |v_{t+1} - v_t|  (Algorithm 1 lines 4-7). The r=1 slice of
    the batched engine loop (core/power.py), kept for single-vector callers.
    """
    v, t_cols, done = batched_power_iteration(
        lambda vv: w_matvec(vv[:, 0])[:, None], v0[:, None], eps, max_iter
    )
    return v[:, 0], t_cols[0], done[0]


def standardize_embedding(v: jax.Array) -> jax.Array:
    """Zero-mean / unit-variance rescale of the 1-D embedding before k-means.

    PIC's embedding has a dynamic range ~1e-5 of its magnitude (values cluster
    around 1/n); standardizing keeps k-means numerically meaningful in f32.
    """
    return (v - jnp.mean(v)) / jnp.maximum(jnp.std(v), 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("k", "max_iter", "kmeans_iters", "affinity_kind",
                     "affinity", "n_vectors", "embedding", "qr_every",
                     "snapshot_iters", "residual_tol"),
)
def pic_reference(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    eps: float | None = None,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    sigma: float | None = None,
    affinity: AffinitySpec | None = None,
    n_vectors: int = 1,
    embedding: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple | None = None,
    residual_tol: float | None = None,
) -> PICResult:
    """Paper Algorithm 1 end-to-end on raw features ``x`` of shape (n, m).

    ``affinity`` (an :class:`AffinitySpec`) runs the dense jnp reference of
    the full graph-construction policy (adaptive local scaling / kNN
    truncation — the oracle the Pallas two-pass build is tested against);
    the legacy ``affinity_kind``/``sigma`` shorthand keeps the classic
    dense builds, including the sigma=None bandwidth heuristic.
    """
    if affinity is not None:
        a = affinity_matrix(x, spec=affinity)
    else:
        a = affinity_matrix(x, kind=affinity_kind, sigma=sigma)
    return pic_from_affinity(
        a, k, key=key, eps=eps, max_iter=max_iter, kmeans_iters=kmeans_iters,
        n_vectors=n_vectors, embedding=embedding, qr_every=qr_every,
        snapshot_iters=snapshot_iters, residual_tol=residual_tol,
    )


@functools.partial(
    jax.jit, static_argnames=("k", "max_iter", "kmeans_iters", "n_vectors",
                              "embedding", "qr_every", "snapshot_iters",
                              "residual_tol")
)
def pic_from_affinity(
    a: jax.Array,
    k: int,
    *,
    key: jax.Array,
    eps: float | None = None,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    n_vectors: int = 1,
    embedding: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple | None = None,
    residual_tol: float | None = None,
) -> PICResult:
    """PIC given a pre-built dense affinity matrix A (paper-faithful path).

    W = D^-1 A is materialized explicitly, exactly as Algorithm 1/2 do.
    v_0 = D / sum(D) (GPIC Algorithm 2 lines 4-5). ``eps`` defaults to the
    paper's 1e-5 / n. ``n_vectors > 1`` adds extra power vectors from random
    starts and clusters the stacked embedding (Lin & Cohen's multi-vector
    extension; beyond-paper robustness option O3). All vectors iterate as
    ONE (n, r) batched state — a single W mat-mat per iteration instead of
    r separate sweeps (core/power.py). ``embedding`` selects the block mode
    ('pic' | 'orthogonal' | 'ensemble', DESIGN.md §10); this oracle path
    runs the block algebra through the bare ``w @ V`` operator (jnp Gram).
    """
    n = a.shape[0]
    if eps is None:
        eps = 1e-5 / n
    d = jnp.sum(a, axis=1)
    # masked normalization: an isolated row (zero or non-finite degree)
    # contributes an exact-zero W row instead of a 1e30-scaled junk one;
    # healthy rows divide bitwise as before (DESIGN.md §12)
    dok = d > 0
    w = jnp.where(dok[:, None], a / jnp.where(dok, d, 1.0)[:, None], 0.0)

    kkm, krand = jax.random.split(key)
    v0 = init_power_vectors(krand, d, n_vectors, dtype=a.dtype)
    v, t_cols, done, emb_raw, status = run_power_embedding(
        lambda vv: w @ vv, v0, eps, max_iter, embedding=embedding,
        qr_every=qr_every, snapshot_iters=snapshot_iters,
        residual_tol=residual_tol)
    emb = standardize_columns(emb_raw)
    labels, _cent = kmeans(kkm, emb, k, iters=kmeans_iters)
    health = HealthReport(
        col_status=status, isolated_rows=count_bad_rows(d),
        n_components=jnp.int32(-1),        # no spec here — probe not armed
        components=jnp.full((n,), -1, jnp.int32))
    return make_pic_result(labels, v, t_cols, done, embedding=embedding,
                           embeddings=emb_raw, health=health)


# ---------------------------------------------------------------------------
# Serial baseline (stands in for the MATLAB implementation the paper times).
# ---------------------------------------------------------------------------


def pic_serial_numpy(
    x: np.ndarray,
    k: int,
    *,
    seed: int = 0,
    eps: float | None = None,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    sigma: float | None = None,
    return_timings: bool = False,
):
    """Row-at-a-time serial PIC. Mirrors the structure the paper profiles:

    an O(n^2 m) affinity loop (their Table-1 bottleneck), explicit RowSum /
    NormMatrix passes, then an un-fused power loop. Intentionally not vectorized
    across rows so the affinity stage dominates like the MATLAB original.
    """
    import time

    n = x.shape[0]
    x = np.asarray(x, np.float64)
    if eps is None:
        eps = 1e-5 / n

    t0 = time.perf_counter()
    if affinity_kind in ("cosine", "cosine_shifted"):
        xn = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
        a = np.empty((n, n), np.float64)
        for i in range(n):  # deliberate serial row loop (see docstring)
            row = xn[i] @ xn.T
            if affinity_kind == "cosine_shifted":
                row = 0.5 * (1.0 + row)
            row[i] = 0.0
            a[i] = row
    else:
        sq = np.sum(x * x, axis=1)
        if sigma is not None:
            sig = float(sigma)
        else:
            # strided sample, matching core.affinity.rbf_bandwidth_heuristic
            # (a leading slice is biased on cluster-ordered inputs; the
            # ceil-division stride spans the whole row range)
            take = min(512, n)
            xs = x[:: max(-(-n // take), 1)][:take]
            sqs = np.sum(xs * xs, axis=1)
            sig = float(np.median(np.sqrt(np.maximum(
                sqs[:, None] + sqs[None, :] - 2 * xs @ xs.T, 0)
                + np.eye(len(xs)) * 1e9)))
        a = np.empty((n, n), np.float64)
        for i in range(n):
            d2 = np.maximum(sq[i] + sq - 2.0 * (x[i] @ x.T), 0.0)
            row = np.exp(-d2 / (2.0 * sig * sig))
            row[i] = 0.0
            a[i] = row
    t_affinity = time.perf_counter() - t0

    t1 = time.perf_counter()
    d = a.sum(axis=1)                    # RowSum kernel
    w = a / np.maximum(d, 1e-30)[:, None]  # NormMatrix kernel
    t_norm = time.perf_counter() - t1

    t1 = time.perf_counter()
    v = d / max(d.sum(), 1e-30)          # Reduction + Norm
    delta = v.copy()
    it = 0
    for it in range(1, max_iter + 1):    # power loop (Multiply/Reduction/Norm)
        wv = w @ v
        v_next = wv / max(np.abs(wv).sum(), 1e-30)
        delta_next = np.abs(v_next - v)
        accel = np.max(np.abs(delta_next - delta))
        v, delta = v_next, delta_next
        if accel <= eps:
            break
    t_power = time.perf_counter() - t1

    t2 = time.perf_counter()
    v_std = (v - v.mean()) / max(v.std(), 1e-30)
    labels, _ = kmeans(jax.random.key(seed), jnp.asarray(v_std)[:, None], k,
                       iters=kmeans_iters)
    labels = np.asarray(labels)
    t_kmeans = time.perf_counter() - t2

    if return_timings:
        return labels, v, {
            "affinity_s": t_affinity,
            "norm_s": t_norm,
            "power_s": t_power,
            "kmeans_s": t_kmeans,
            "total_s": t_affinity + t_norm + t_power + t_kmeans,
            "n_iter": it,
        }
    return labels, v
