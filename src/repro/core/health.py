"""Validation + diagnostics layer: typed errors, health codes, graph probes.

Every GPIC entry point either succeeds with a diagnosable result or fails
with a typed, actionable error — never silent garbage (DESIGN.md §12).
Three pieces live here:

  - The :class:`GPICError` hierarchy: the exceptions the front door
    (``run_gpic``) raises for degenerate inputs and unrecoverable runs.
    ``InvalidInputError`` doubles as a ``ValueError`` so pre-existing
    ``except ValueError`` callers keep working.
  - :class:`HealthReport` + the ``COL_*`` per-column status codes: the
    device-side diagnostics every entry point threads through
    ``PICResult.health``. The arrays are computed THROUGH the operator's
    reduction primitives, so the local and sharded engines report
    identical diagnostics (the same parity discipline as the loop itself).
  - The degenerate-graph probes: :func:`count_bad_rows` (isolated-row
    count from the degree vector — the sweep itself needs no masking, see
    :func:`degree_guard`), :func:`graph_component_probe` (on-device
    connected-component check for truncated kNN graphs, via nonnegative
    reachability sweeps), and :func:`degree_guard` (masked-reciprocal
    utility for host-side callers).

The loop-side latches (zero-column, non-finite, stall) live in
``core/power.py``; the kernel-fallback record lives in ``kernels/ops.py``;
this module only defines the vocabulary they share.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Typed errors
# ---------------------------------------------------------------------------


class GPICError(Exception):
    """Base of every typed GPIC failure (catch-all for callers)."""


class InvalidInputError(GPICError, ValueError):
    """The input can never cluster: bad shape, n < k, empty, constant."""


class NonFiniteInputError(InvalidInputError):
    """The feature matrix contains NaN/Inf (opt out via sanitize=True)."""


class DegenerateGraphError(GPICError):
    """The affinity graph carries no usable structure (e.g. every row
    isolated: all similarities underflowed to exact zero)."""


class PowerDivergenceError(GPICError):
    """Every power-iteration column went non-finite or lost all mass —
    there is no embedding left to cluster."""


class CheckpointCorruptError(GPICError):
    """A convergence-carry snapshot failed its integrity check (per-leaf
    checksum mismatch, truncated/missing leaf file, unreadable manifest).
    The supervisor skips the corrupt snapshot back to the previous valid
    step (noted ``checkpoint_skipped:<dir>``) instead of crashing."""


class StragglerTimeout(GPICError):
    """A bounded execution segment exceeded the configured wall-clock
    budget (``GPICConfig.straggler_timeout``) — the watchdog signal the
    supervisor classifies as retryable, resuming the segment from the
    last snapshot instead of re-running from sweep 0."""


# ---------------------------------------------------------------------------
# Per-column status codes (bitmask — a column can stall AND hit max_iter)
# ---------------------------------------------------------------------------

COL_OK = 0          #: converged by the acceleration (or residual) rule
COL_MAXITER = 1     #: ran to the iteration cap without converging
COL_STALLED = 2     #: acceleration stopped improving for STALL_PATIENCE
#                      sweeps (periodic/oscillating trajectory) — diagnostic
#                      only, the column keeps iterating
COL_NONFINITE = 4   #: NaN/Inf appeared in the column; it was zeroed+latched
COL_ZERO = 8        #: the column's L1 mass hit exact zero; latched

_STATUS_NAMES = (
    (COL_MAXITER, "maxiter"),
    (COL_STALLED, "stalled"),
    (COL_NONFINITE, "nonfinite"),
    (COL_ZERO, "zero"),
)

#: note prefixes that record a RECOVERY event (the supervisor resumed,
#: retried, or skipped a corrupt snapshot) rather than residual damage —
#: a run whose only notes are recovery notes and whose arrays are clean
#: classifies 'recovered', not 'degraded' (ClusteringFaultHarness)
RECOVERY_NOTE_PREFIXES = (
    "resumed:",
    "retry:",
    "straggler:",
    "checkpoint_skipped:",
    "kernel_fallback_retried:",
    "kernel_fallback_resumed:",
)


def is_recovery_note(note: str) -> bool:
    """True when ``note`` records a supervisor recovery event (resume /
    retry / corrupt-snapshot skip) rather than residual result damage."""
    return note.startswith(RECOVERY_NOTE_PREFIXES)


def describe_status(code: int) -> tuple[str, ...]:
    """Human-readable flag names for one column's status bitmask."""
    code = int(code)
    if code == COL_OK:
        return ("ok",)
    return tuple(name for bit, name in _STATUS_NAMES if code & bit)


# ---------------------------------------------------------------------------
# HealthReport
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class HealthReport:
    """Per-run diagnostics carried on ``PICResult.health``.

    All array fields are computed through the operator's reduction
    primitives inside the one convergence engine, so a sharded run reports
    bitwise the same values as the local run of the same problem.
    """
    col_status: jax.Array     # (r,) int32 COL_* bitmask per power column
    isolated_rows: jax.Array  # () int32 — rows whose degree is not > 0
    #                           (exact-zero kNN/underflow rows AND non-finite
    #                           degrees both count: neither can anchor a row)
    n_components: jax.Array   # () int32 — components found by the kNN-graph
    #                           probe; -1 = probe not run (dense spec);
    #                           max_components+1 = capped ("at least")
    components: jax.Array     # (n,) int32 per-row component id (-1 unprobed)
    #: host-side event strings (sanitization applied, kernel fallbacks...)
    #: — static metadata attached by the front door, not a traced leaf
    notes: tuple = field(metadata=dict(static=True), default=())

    def to_dict(self) -> dict:
        """Host-side dict view (concrete results only) — the per-request
        status object the serving path returns alongside labels.

        ``status`` classifies the whole run: 'ok' (clean arrays, no
        notes), 'recovered' (clean arrays, but the supervisor resumed /
        retried / skipped a corrupt snapshot on the way — the recovery
        history is in ``notes``), or 'degraded' (bad columns, isolated
        rows, or a non-recovery event such as sanitization or an
        un-retried kernel fallback).
        """
        import numpy as np
        status = np.asarray(self.col_status)
        codes = status.tolist()
        bad_columns = sum(1 for c in codes if c != COL_OK)
        iso = int(self.isolated_rows)
        recovery = [n for n in self.notes if is_recovery_note(n)]
        damage = [n for n in self.notes if not is_recovery_note(n)]
        if bad_columns or iso or damage:
            run_status = "degraded"
        elif recovery:
            run_status = "recovered"
        else:
            run_status = "ok"
        return {
            "status": run_status,
            "col_status": [describe_status(c) for c in codes],
            "bad_columns": bad_columns,
            "isolated_rows": iso,
            "n_components": int(self.n_components),
            "notes": list(self.notes),
            "recovery": recovery,
        }

    def summary(self) -> str:
        """One human-readable line of the run's health (concrete results
        only) — status class, bad-column / isolated-row counts, and the
        notes (including the supervisor's retry/resume history)."""
        d = self.to_dict()
        parts = [
            f"status={d['status']}",
            f"bad_columns={d['bad_columns']}/{len(d['col_status'])}",
            f"isolated_rows={d['isolated_rows']}",
        ]
        if d["n_components"] >= 0:
            parts.append(f"n_components={d['n_components']}")
        flagged = [f"{i}:{'+'.join(f)}" for i, f in enumerate(d["col_status"])
                   if f != ("ok",)]
        if flagged:
            parts.append("cols[" + " ".join(flagged) + "]")
        if d["notes"]:
            parts.append("notes[" + "; ".join(d["notes"]) + "]")
        return "GPIC health: " + " ".join(parts)


def empty_health(r: int, n: int) -> HealthReport:
    """An all-OK report (used by paths that compute no diagnostics)."""
    return HealthReport(
        col_status=jnp.zeros((r,), jnp.int32),
        isolated_rows=jnp.int32(0),
        n_components=jnp.int32(-1),
        components=jnp.full((n,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Zero-degree guards (DESIGN.md §12)
# ---------------------------------------------------------------------------


def degree_guard(u: jax.Array, d: jax.Array) -> jax.Array:
    """(A V) / d with rows of non-positive or non-finite degree masked to
    exact zero — a utility for host-side / out-of-band callers.

    The sweep kernels themselves keep the floored
    ``u / jnp.maximum(d, 1e-30)`` divide, which is already zero-degree
    safe: for a nonnegative A, d = 0 means the whole A row is zero, so u
    is an exact 0 and the floor returns exactly 0; a NaN degree propagates
    NaN into the iterate, where the loop's COL_NONFINITE latch catches and
    quarantines it. The kernel divide form is also PINNED: this masked
    variant is value-identical on healthy rows but perturbs interpret-mode
    XLA fusion enough to break the local/sharded trajectory-parity
    discipline (DESIGN.md §12), so it must not be substituted into the
    sweep path. ``u`` is (n, r) or (n,); ``d`` (n,).
    """
    ok = d > 0
    safe = jnp.where(ok, d, 1.0)
    if u.ndim == 2:
        return jnp.where(ok[:, None], u / safe[:, None], 0.0)
    return jnp.where(ok, u / safe, 0.0)


def count_bad_rows(d: jax.Array, sum_fn=None) -> jax.Array:
    """() int32 count of rows whose degree cannot anchor them (not > 0).
    ``sum_fn`` finishes the cross-chunk combine (identity locally)."""
    local = jnp.sum(jnp.where(d > 0, 0, 1).astype(jnp.int32))
    return local if sum_fn is None else sum_fn(local)


# ---------------------------------------------------------------------------
# Disconnected-component probe
# ---------------------------------------------------------------------------


def graph_component_probe(op, n_total: int, *, row_offset=0,
                          max_components: int = 8, max_sweeps: int = 32):
    """On-device component check of the (truncated) affinity graph.

    Repeated nonnegative reachability expansion: starting from an indicator
    on the lowest-index unvisited row, one ``op.matmat`` sweep (unioned
    with one ``op.matmat_t`` sweep when the operator binds it) adds every
    row with a nonzero affinity entry into the reached set; the expansion
    runs until a fixed point, that set becomes one component, and the next
    seed is the lowest unvisited row — up to ``max_components`` seeds.

    Exactness across engines: for a nonnegative matrix and a {0,1}
    indicator the POSITIVITY pattern of A@v (and of Aᵀ@v) is independent
    of summation order (a sum of nonnegative terms is positive iff any
    term is), so the local and sharded engines (whose sweeps differ only
    in reduction order) compute bitwise-identical probe results — unlike
    the iterates themselves, which agree only to reduction-order noise.

    Symmetrized reachability: the kNN-truncated graph is DIRECTED (per-row
    top-k), and a forward sweep alone only grows along reverse edges — a
    row nobody selects (in-degree 0) is then unreachable from its own
    neighbors and gets misreported as a separate component even though the
    weak cluster is intact. Operators over truncated specs therefore bind
    ``matmat_t`` and the expansion walks A + Aᵀ reachability — the WEAKLY
    connected components, which is the quantity that decides whether power
    iteration mass can spread (W = D⁻¹A moves mass along either direction
    of an undirected similarity). Without ``matmat_t`` (symmetric dense
    specs) the forward sweep already covers both directions. Rows are
    visited at most ``max_sweeps`` hops out; if unvisited rows remain
    after ``max_components`` seeds the count reports
    ``max_components + 1`` ("at least").

    Returns ``(n_components () int32, comp (n_local,) int32)`` with comp
    ids in discovery order and -1 for never-reached rows.
    """
    n_local = op.degree.shape[0]
    gidx = row_offset + jnp.arange(n_local, dtype=jnp.int32)

    def expand(reached):
        def cond(c):
            _reached, grew, s = c
            return grew & (s < max_sweeps)

        def body(c):
            reached, _grew, s = c
            ind = reached.astype(jnp.float32)[:, None]
            u = op.matmat(ind)[:, 0]
            new = reached | (u > 0)
            if op.matmat_t is not None:
                new = new | (op.matmat_t(ind)[:, 0] > 0)
            grew = op.sum(
                jnp.sum((new & ~reached).astype(jnp.int32))) > 0
            return new, grew, s + 1

        reached, _, _ = jax.lax.while_loop(
            cond, body, (reached, jnp.bool_(True), jnp.int32(0)))
        return reached

    def comp_cond(c):
        _comp, count, visited = c
        unvisited = op.sum(jnp.sum((~visited).astype(jnp.int32)))
        return (unvisited > 0) & (count < max_components)

    def comp_body(c):
        comp, count, visited = c
        cand = jnp.where(visited, n_total, gidx)
        seed = -op.max(-jnp.min(cand))          # global min unvisited index
        reached = expand(gidx == seed)
        comp = jnp.where(reached & (comp < 0), count, comp)
        return comp, count + 1, visited | reached

    comp, count, visited = jax.lax.while_loop(
        comp_cond, comp_body,
        (jnp.full((n_local,), -1, jnp.int32), jnp.int32(0),
         jnp.zeros((n_local,), bool)))
    leftover = op.sum(jnp.sum((~visited).astype(jnp.int32)))
    return count + jnp.where(leftover > 0, 1, 0).astype(jnp.int32), comp


# ---------------------------------------------------------------------------
# Front-door input validation (host-side; run_gpic)
# ---------------------------------------------------------------------------


def validate_features(x, k: int, *, sanitize: bool = False):
    """Front-door feature checks. Returns ``(x, notes)`` — possibly
    sanitized — or raises a typed error.

    Raises :class:`InvalidInputError` for shapes that can never cluster
    (ndim != 2, empty, n < k) and for an all-identical feature matrix
    (every pairwise similarity equal → the embedding is constant);
    :class:`NonFiniteInputError` for NaN/Inf features unless
    ``sanitize=True``, which zero-fills them and records the event in the
    returned notes. Value checks need concrete data; under a tracer
    (run_gpic called inside a caller's jit) they are skipped and the
    device-side latches carry the load.
    """
    notes: list[str] = []
    if x.ndim != 2:
        raise InvalidInputError(
            f"features must be a (n, m) matrix, got shape {x.shape}")
    n, m = x.shape
    if n == 0 or m == 0:
        raise InvalidInputError(f"empty feature matrix (shape {x.shape})")
    if n < k:
        raise InvalidInputError(
            f"cannot form k={k} clusters from n={n} points")
    if isinstance(x, jax.core.Tracer):
        return x, tuple(notes)
    x = jnp.asarray(x)
    n_bad = int(jnp.sum(~jnp.isfinite(x)))
    if n_bad:
        if not sanitize:
            raise NonFiniteInputError(
                f"{n_bad} non-finite feature value(s); pass sanitize=True "
                "to zero-fill them (recorded in PICResult.health.notes)")
        x = jnp.nan_to_num(x, nan=0.0, posinf=0.0, neginf=0.0)
        notes.append(f"sanitized:{n_bad}_nonfinite_features")
    if bool(jnp.all(x == x[0:1])):
        raise InvalidInputError(
            "all feature rows are identical — every pairwise affinity is "
            "equal and the power embedding is constant; clustering is "
            "undefined on this input")
    return x, tuple(notes)


def raise_for_health(health: HealthReport, n: int) -> None:
    """Post-run host check: raise when the result is unusable (ALL rows
    isolated / ALL columns dead); partial damage returns with the report
    populated instead. No-op on traced values (jit'd caller)."""
    if isinstance(health.col_status, jax.core.Tracer):
        return
    import numpy as np
    iso = int(health.isolated_rows)
    if iso >= n:
        raise DegenerateGraphError(
            f"every one of the {n} rows is isolated (zero degree) — the "
            "affinity graph is empty; widen sigma / raise knn_k")
    status = np.asarray(health.col_status)
    fatal = COL_NONFINITE | COL_ZERO
    if status.size and bool(((status & fatal) != 0).all()):
        names = [describe_status(c) for c in status.tolist()]
        raise PowerDivergenceError(
            f"every power-iteration column went dead ({names}) — no "
            "embedding left to cluster; check feature scaling "
            f"({iso}/{n} rows isolated)")
