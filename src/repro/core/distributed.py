"""Distributed GPIC via shard_map — the paper's multi-GPU future work, built
for the production mesh (DESIGN.md §3).

Layouts:
  explicit path:     A row-stripes sharded over the given mesh axes; X and V
                     replicated via all-gather (X once, V per step — O(n r)
                     bytes/step vs O(n²/P) compute: collective-light).
  matrix-free path:  X̂ row-sharded; per step one psum of an (m, r) block and
                     two (r,) psums. Collectives O(m r) per step — this is
                     the configuration that scales to thousands of nodes.

Both paths run the batched multi-vector engine state (core/power.py
semantics): ``n_vectors`` power vectors iterate as one (n, r) matrix, one
stripe sweep per iteration regardless of r, with per-column freezing so
every column reproduces its dedicated single-vector trajectory.

The final k-means runs on the (already replicated) (n, r) embedding
identically on every device — deterministic, no collective needed.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .affinity import AffinityKind, row_normalize_features
from .kmeans import kmeans
from .pic import PICResult
from .power import random_start_vectors, standardize_columns


def _axis_tuple(axes) -> tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _replicated_power_loop(matmat_local, v0_full, n_loc, axes, eps, max_iter,
                           idx):
    """Batched power loop; each device owns rows [idx*n_loc, (idx+1)*n_loc).

    ``matmat_local`` maps a full replicated (n, r) V to the local
    (n_loc, r) chunk of (A V / d). Per-column freezing matches
    core.power.batched_power_iteration exactly, with the L1/∞-norm
    reductions psum/pmax'd over the mesh axes. Returns the *replicated*
    final V plus per-column iteration stats.
    """
    r = v0_full.shape[1]

    def cond(state):
        t, _v, _delta, done, _t_cols = state
        return jnp.logical_and(t < max_iter, jnp.logical_not(jnp.all(done)))

    def body(state):
        t, v_full, delta_loc, done, t_cols = state
        u_loc = matmat_local(v_full)                            # (n_loc, r)
        l1 = jax.lax.psum(jnp.sum(jnp.abs(u_loc), axis=0), axes)    # (r,)
        v_loc = u_loc / jnp.maximum(l1, 1e-30)[None, :]
        v_prev_loc = jax.lax.dynamic_slice(
            v_full, (idx * n_loc, 0), (n_loc, r))
        delta_next = jnp.abs(v_loc - v_prev_loc)
        accel = jax.lax.pmax(
            jnp.max(jnp.abs(delta_next - delta_loc), axis=0), axes)  # (r,)
        v_loc = jnp.where(done[None, :], v_prev_loc, v_loc)
        delta_next = jnp.where(done[None, :], delta_loc, delta_next)
        t_cols = t_cols + jnp.where(done, 0, 1).astype(jnp.int32)
        done = jnp.logical_or(done, accel <= eps)
        v_next_full = jax.lax.all_gather(v_loc, axes, axis=0, tiled=True)
        return t + 1, v_next_full, delta_next, done, t_cols

    delta0 = jax.lax.dynamic_slice(v0_full, (idx * n_loc, 0), (n_loc, r))
    state = (jnp.int32(0), v0_full, delta0,
             jnp.zeros((r,), bool), jnp.zeros((r,), jnp.int32))
    _t, v_full, _d, done, t_cols = jax.lax.while_loop(cond, body, state)
    return v_full, t_cols, done


def _stripe_affinity(x_loc, x_full, row0, kind: str, sigma: float):
    """Local (n_loc, n) affinity stripe with global-diagonal masking."""
    n_loc = x_loc.shape[0]
    n = x_full.shape[0]
    if kind in ("cosine", "cosine_shifted"):
        a = x_loc @ x_full.T
        if kind == "cosine_shifted":
            a = 0.5 * (1.0 + a)
    elif kind == "rbf":
        sq_l = jnp.sum(x_loc * x_loc, axis=1)
        sq_f = jnp.sum(x_full * x_full, axis=1)
        d2 = jnp.maximum(sq_l[:, None] + sq_f[None, :] - 2.0 * (x_loc @ x_full.T),
                         0.0)
        a = jnp.exp(-d2 / (2.0 * sigma * sigma))
    else:
        raise ValueError(kind)
    rows = row0 + jnp.arange(n_loc)[:, None]
    cols = jnp.arange(n)[None, :]
    return a * (rows != cols)


@functools.partial(
    jax.jit,
    static_argnames=("k", "mesh", "shard_axes", "max_iter", "kmeans_iters",
                     "affinity_kind", "sigma", "eps_scale", "a_dtype",
                     "fold_shift", "n_vectors"),
)
def distributed_gpic(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    mesh: Mesh,
    shard_axes: str | Sequence[str] = "data",
    eps_scale: float = 1e-5,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    sigma: float = 1.0,
    a_dtype=jnp.float32,
    fold_shift: bool = False,
    n_vectors: int = 1,
) -> PICResult:
    """Explicit-A distributed GPIC (paper-faithful math, row-striped A).

    Beyond-paper options (identical math, recorded in EXPERIMENTS §Perf):
      a_dtype=bf16 (O4): store the stripe in bf16; per-iteration A reads
        halve; reductions stay f32-accumulated.
      fold_shift (O5, cosine_shifted only): store RAW A' = X̂X̂ᵀ and fold
        the (1+a)/2 transform + diagonal mask into the mat-mat algebra
        ((AV)_i = 0.5(ΣV + (A'V)_i) − V_i, using a'_ii = 1) — the O(n²/P)
        transform/mask passes over A disappear from the build.
      n_vectors=r: the multi-vector engine — r power vectors in one
        (n, r) state, ONE stripe sweep per iteration (DESIGN.md §4).
    """
    axes = _axis_tuple(shard_axes)
    n = x.shape[0]
    eps = eps_scale / n
    fold = fold_shift and affinity_kind == "cosine_shifted"
    kkm, krand = jax.random.split(key)
    u0t = random_start_vectors(krand, n, n_vectors)

    def fn(x_loc, key, u0t):
        idx = jax.lax.axis_index(axes)
        n_loc = x_loc.shape[0]
        row0 = idx * n_loc
        if affinity_kind != "rbf":
            x_loc = row_normalize_features(x_loc)
        x_full = jax.lax.all_gather(x_loc, axes, axis=0, tiled=True)

        if fold:
            a_loc = jax.lax.dot_general(
                x_loc, x_full, (((1,), (1,)), ((), ())),
                preferred_element_type=a_dtype)   # bf16 out: single write
            ones = jnp.ones((n,), jnp.float32)
            d_raw = jax.lax.dot_general(
                a_loc, ones.astype(a_dtype), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            # d_i = sum_{j!=i} (1 + a'_ij)/2 = 0.5 (n - 2 + (A'1)_i)
            d_loc = 0.5 * (n - 2.0 + d_raw)
        else:
            a_f32 = _stripe_affinity(x_loc, x_full, row0, affinity_kind,
                                     sigma)
            d_loc = jnp.sum(a_f32, axis=1)      # degree in f32 (one pass)
            a_loc = a_f32.astype(a_dtype)
        dsum = jax.lax.psum(jnp.sum(d_loc), axes)
        v0_loc = d_loc / jnp.maximum(dsum, 1e-30)
        v0_full = jax.lax.all_gather(v0_loc, axes, axis=0, tiled=True)
        v0_full = jnp.concatenate([v0_full[:, None], u0t], axis=1)

        def mm(v_full):
            av = jax.lax.dot_general(
                a_loc, v_full.astype(a_dtype), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)   # bf16 read, f32 accum
            if fold:
                sv = jnp.sum(v_full, axis=0)                    # (r,)
                v_own = jax.lax.dynamic_slice(
                    v_full, (row0, 0), (n_loc, v_full.shape[1]))
                av = 0.5 * (sv[None, :] + av) - v_own
            return av / jnp.maximum(d_loc, 1e-30)[:, None]

        v_full, t_cols, done = _replicated_power_loop(
            mm, v0_full, n_loc, axes, eps, max_iter, idx)
        emb = standardize_columns(v_full)
        labels, _ = kmeans(key, emb, k, iters=kmeans_iters)
        return labels, v_full[:, 0], t_cols[0], done[0]

    spec_x = P(axes)
    out = shard_map(
        fn, mesh=mesh,
        in_specs=(spec_x, P(), P()),
        out_specs=(P(), P(), P(), P()),
        check_rep=False,
    )(x, kkm, u0t)
    labels, v, t, done = out
    return PICResult(labels=labels, embedding=v, n_iter=t, converged=done)


@functools.partial(
    jax.jit,
    static_argnames=("k", "mesh", "shard_axes", "max_iter", "kmeans_iters",
                     "affinity_kind", "eps_scale", "n_vectors"),
)
def distributed_gpic_matrix_free(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    mesh: Mesh,
    shard_axes: str | Sequence[str] = "data",
    eps_scale: float = 1e-5,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    n_vectors: int = 1,
) -> PICResult:
    """Matrix-free distributed GPIC (O2): psum(m r) per step, scales to 1000s
    of nodes. Cosine affinity kinds only (they factor; DESIGN.md §2)."""
    axes = _axis_tuple(shard_axes)
    n = x.shape[0]
    eps = eps_scale / n
    if affinity_kind not in ("cosine", "cosine_shifted"):
        raise ValueError("matrix-free path needs a factorable affinity")
    kkm, krand = jax.random.split(key)
    u0t = random_start_vectors(krand, n, n_vectors)

    def fn(x_loc, key, u0t):
        idx = jax.lax.axis_index(axes)
        n_loc = x_loc.shape[0]
        r = n_vectors
        xn_loc = row_normalize_features(x_loc)

        def mm_raw(v_loc):
            # A V  =  f(X̂ (X̂ᵀ V)) − V, with the X̂ᵀV partial psum'd (O(m r))
            s = jax.lax.psum(xn_loc.T @ v_loc, axes)          # (m, r)
            av = xn_loc @ s - v_loc
            if affinity_kind == "cosine_shifted":
                vsum = jax.lax.psum(jnp.sum(v_loc, axis=0), axes)   # (r,)
                av = 0.5 * (vsum[None, :] + xn_loc @ s) - v_loc
            return av

        d_loc = mm_raw(jnp.ones((n_loc, 1), xn_loc.dtype))[:, 0]
        dsum = jax.lax.psum(jnp.sum(d_loc), axes)
        v_loc = (d_loc / jnp.maximum(dsum, 1e-30))[:, None]
        u0t_loc = jax.lax.dynamic_slice(
            u0t, (idx * n_loc, 0), (n_loc, u0t.shape[1]))
        v_loc = jnp.concatenate([v_loc, u0t_loc], axis=1)       # (n_loc, r)
        delta_loc = v_loc

        def cond(state):
            t, _v, _delta, done, _t_cols = state
            return jnp.logical_and(t < max_iter,
                                   jnp.logical_not(jnp.all(done)))

        def body(state):
            t, v_loc, delta_loc, done, t_cols = state
            u_loc = mm_raw(v_loc) / jnp.maximum(d_loc, 1e-30)[:, None]
            l1 = jax.lax.psum(jnp.sum(jnp.abs(u_loc), axis=0), axes)  # (r,)
            v_next = u_loc / jnp.maximum(l1, 1e-30)[None, :]
            delta_next = jnp.abs(v_next - v_loc)
            accel = jax.lax.pmax(
                jnp.max(jnp.abs(delta_next - delta_loc), axis=0), axes)
            v_next = jnp.where(done[None, :], v_loc, v_next)
            delta_next = jnp.where(done[None, :], delta_loc, delta_next)
            t_cols = t_cols + jnp.where(done, 0, 1).astype(jnp.int32)
            done = jnp.logical_or(done, accel <= eps)
            return t + 1, v_next, delta_next, done, t_cols

        state = (jnp.int32(0), v_loc, delta_loc,
                 jnp.zeros((r,), bool), jnp.zeros((r,), jnp.int32))
        _t, v_loc, _d, done, t_cols = jax.lax.while_loop(cond, body, state)

        v_full = jax.lax.all_gather(v_loc, axes, axis=0, tiled=True)  # once
        emb = standardize_columns(v_full)
        labels, _ = kmeans(key, emb, k, iters=kmeans_iters)
        return labels, v_full[:, 0], t_cols[0], done[0]

    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axes), P(), P()),
        out_specs=(P(), P(), P(), P()),
        check_rep=False,
    )(x, kkm, u0t)
    labels, v, t, done = out
    return PICResult(labels=labels, embedding=v, n_iter=t, converged=done)


def shard_points(x, mesh: Mesh, shard_axes="data"):
    """Places (n, m) host data row-sharded on the mesh (pads n to P)."""
    axes = _axis_tuple(shard_axes)
    sharding = NamedSharding(mesh, P(axes))
    return jax.device_put(jnp.asarray(x), sharding)
