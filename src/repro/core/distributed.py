"""Distributed GPIC via shard_map — the paper's multi-GPU future work, built
for the production mesh (DESIGN.md §3, §9).

There is no distributed power loop and no distributed affinity math in this
module: every path assembles a sharded :class:`~repro.core.power.PowerOperator`
(core/operators.py) — the SAME Pallas kernel dispatch the single-device
engines use, run on each device's row stripe inside ``shard_map`` — and
hands it to the ONE convergence engine, ``core.power.batched_power_iteration``.
The engine's ``sum``/``max``/``all_gather`` primitives are bound to
``psum``/``pmax``/``all_gather`` over the mesh axes. The explicit path
compiles the same tiled kernel program as the single-device build (tiles
keyed on the global n); the streaming ring tiles per (n/P) block and
accumulates blocks in rotated order, so its trajectories agree with the
single-device engine at the ulp level rather than bitwise (DESIGN.md §9).

Layouts:
  explicit path:      A row-stripes built by the Pallas affinity kernel
                      (bf16 A-storage O4 and fold_shift O5 supported); X and
                      V replicated via all-gather (X once, V per step —
                      O(n r) bytes/step vs O(n²/P) compute).
  streaming path:     row-striped features, NO gathered copies: each sweep
                      ring-rotates the (n/P, m) feature blocks with
                      ppermute while the streaming kernel regenerates
                      affinity stripe tiles on the fly. O(n·m/P) peak
                      memory per device and every affinity kind — the
                      production configuration.
  matrix-free path:   X̂ row-sharded; per step one psum of an (m, r) block
                      and one (r,) psum. Collectives O(m r) per step — the
                      configuration that scales to thousands of nodes.

All paths run the batched multi-vector engine state (core/power.py):
``n_vectors`` power vectors iterate as one (n_loc, r) local chunk, one
stripe sweep per iteration regardless of r, with per-column freezing so
every column reproduces its dedicated single-vector trajectory.

The final k-means runs on the (gathered, replicated) (n, r) embedding
identically on every device — deterministic, no collective needed.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .affinity import AffinityKind, AffinitySpec, as_affinity_spec
from .health import HealthReport, count_bad_rows, graph_component_probe
from .kmeans import kmeans
from .operators import (
    _axis_tuple,
    mesh_reductions,
    sharded_explicit_operator,
    sharded_matrix_free_operator,
    sharded_streaming_operator,
)
from .pic import PICResult, make_pic_result
from .power import (
    PowerCarry,
    backfill_snapshots,
    ensemble_embedding,
    finalize_power_carry,
    init_power_carry,
    init_power_vectors_local,
    power_iteration_segment,
    random_start_vectors,
    run_power_embedding,
    standardize_columns,
)



def _mesh_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def _build_sharded_operator(x_loc, axes, mesh_size, engine, spec, *,
                            a_dtype=jnp.float32, fold_shift=False, tile=None,
                            use_pallas=True, block_sparse=True,
                            inject_ring_fault=None):
    """The ONE sharded operator construction (inside the shard_map body) —
    shared by the monolithic entry points and the segmented (resumable)
    ones so both trace the identical build (DESIGN.md §14)."""
    if engine == "explicit":
        return sharded_explicit_operator(
            x_loc, axes=axes, spec=spec, a_dtype=a_dtype,
            fold_shift=fold_shift, tile=tile, use_pallas=use_pallas,
            block_sparse=block_sparse)
    if engine == "streaming":
        return sharded_streaming_operator(
            x_loc, axes=axes, mesh_size=mesh_size, spec=spec,
            tile=tile, use_pallas=use_pallas, block_sparse=block_sparse,
            inject_fault=inject_ring_fault)
    if engine == "matrix_free":
        return sharded_matrix_free_operator(x_loc, axes=axes, spec=spec,
                                            use_pallas=use_pallas)
    raise ValueError(f"unknown engine {engine!r} "
                     "(expected 'explicit' or 'streaming')")


def _local_slice(idx, n_loc, arr):
    """The (n_loc, ...) row chunk of a replicated array at device ``idx``."""
    return jax.lax.dynamic_slice_in_dim(arr, idx * n_loc, n_loc, axis=0)


def _run_sharded(op, axes, *, key, u0t, k, eps, max_iter, kmeans_iters,
                 n_total, embedding="pic", qr_every=1, snapshot_iters=None,
                 residual_tol=None, force_reference=False, probe=False):
    """Seed the local engine state from the operator's degrees, run THE
    convergence engine, gather once, and k-means the replicated embedding.

    The embedding-mode routing is the same :func:`run_power_embedding` the
    local entry points use: the QR step's Gram partials run on each
    device's chunk and are finished by the operator's ``psum`` binding, and
    ensemble snapshots are taken on the local chunk and gathered once after
    the loop — the sharded block algebra IS the single-device one. The
    health arrays (per-column status, isolated-row count, the component
    probe when ``probe`` arms) likewise finish through the operator's
    reductions, so a sharded run reports the same diagnostics as the local
    run of the same problem (DESIGN.md §12).
    Returns (labels, v_full, emb_full, t_cols, done, status, iso, n_comp,
    comp_full): the replicated final (n, r) engine state, the replicated
    (n, c) matrix that was clustered (the same array unless ensemble
    widened it to c = r·S), and the replicated health arrays.
    """
    idx = jax.lax.axis_index(_axis_tuple(axes))
    n_loc = op.degree.shape[0]
    u0t_loc = _local_slice(idx, n_loc, u0t)
    v0_loc = init_power_vectors_local(
        op.degree, u0t_loc, sum_fn=op.sum, dtype=jnp.float32)
    v_loc, t_cols, done, emb_loc, status = run_power_embedding(
        op, v0_loc, eps, max_iter, embedding=embedding, qr_every=qr_every,
        snapshot_iters=snapshot_iters, residual_tol=residual_tol)
    emb_full = op.all_gather(emb_loc)                   # once, after the loop
    v_full = emb_full if emb_loc is v_loc else op.all_gather(v_loc)
    emb = standardize_columns(emb_full)
    labels, _ = kmeans(key, emb, k, iters=kmeans_iters,
                       force_reference=force_reference)
    iso = count_bad_rows(op.degree, sum_fn=op.sum)
    if probe:
        n_comp, comp_loc = graph_component_probe(
            op, n_total, row_offset=idx * n_loc)
        comp_full = op.all_gather(comp_loc)
    else:
        n_comp = jnp.int32(-1)
        comp_full = jnp.full((n_total,), -1, jnp.int32)
    return (labels, v_full, emb_full, t_cols, done,
            status, iso, n_comp, comp_full)


@functools.partial(
    jax.jit,
    static_argnames=("k", "mesh", "shard_axes", "max_iter", "kmeans_iters",
                     "affinity_kind", "sigma", "affinity", "eps_scale",
                     "a_dtype", "fold_shift", "n_vectors", "engine", "tile",
                     "use_pallas", "embedding", "qr_every", "snapshot_iters",
                     "residual_tol", "probe_components", "block_sparse",
                     "inject_ring_fault"),
)
def distributed_gpic(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    mesh: Mesh,
    shard_axes: str | Sequence[str] = "data",
    eps_scale: float = 1e-5,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    sigma: float = 1.0,
    affinity: AffinitySpec | None = None,
    a_dtype=jnp.float32,
    fold_shift: bool = False,
    n_vectors: int = 1,
    engine: str = "explicit",
    tile: int | None = None,
    use_pallas: bool = True,
    embedding: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple | None = None,
    residual_tol: float | None = None,
    probe_components: bool = True,
    block_sparse: bool = True,
    inject_ring_fault: tuple | None = None,
) -> PICResult:
    """Sharded GPIC on the Pallas kernels (paper-faithful math, row stripes).

    Engines (mirroring single-device ``gpic``):
      engine='explicit'   per-device (n/P, n) stripe of the Pallas A build;
                          V replicated per sweep. Beyond-paper options:
                          a_dtype=bf16 (O4) halves per-iteration A reads;
                          fold_shift (O5, cosine_shifted only) stores raw
                          masked cosine and folds the shift into an O(n r)
                          epilogue.
      engine='streaming'  A-free ring: feature blocks rotate around the
                          mesh with ppermute while affinity stripe tiles
                          regenerate on the fly. O(n·m/P) peak memory, all
                          affinity kinds — the production configuration.

    ``n_vectors=r`` runs the multi-vector engine — r power vectors in one
    (n, r) state, ONE stripe sweep per iteration (DESIGN.md §4).
    ``embedding`` selects the block mode ('pic' | 'orthogonal' |
    'ensemble', DESIGN.md §10) — the QR/snapshot algebra runs through the
    operator's reduction primitives, so it is the single-device algebra.

    ``probe_components`` runs the on-device disconnected-component check
    when the spec truncates (DESIGN.md §12); ``inject_ring_fault``
    (streaming engine only) poisons one ring stage's consumed block with
    NaN — the fault-injection hook behind tests/test_robustness.py.
    """
    axes = _axis_tuple(shard_axes)
    n = x.shape[0]
    eps = eps_scale / n
    mesh_size = _mesh_size(mesh, axes)
    spec = as_affinity_spec(affinity, kind=affinity_kind, sigma=sigma)
    spec.validate_for_n(n)
    if inject_ring_fault is not None and engine != "streaming":
        raise ValueError(
            "inject_ring_fault targets the streaming ring; "
            f"engine={engine!r} has no ring stages")
    kkm, krand = jax.random.split(key)
    u0t = random_start_vectors(krand, n, n_vectors)

    def fn(x_loc, key, u0t):
        if engine not in ("explicit", "streaming"):
            raise ValueError(f"unknown engine {engine!r} "
                             "(expected 'explicit' or 'streaming')")
        op = _build_sharded_operator(
            x_loc, axes, mesh_size, engine, spec, a_dtype=a_dtype,
            fold_shift=fold_shift, tile=tile, use_pallas=use_pallas,
            block_sparse=block_sparse, inject_ring_fault=inject_ring_fault)
        return _run_sharded(op, axes, key=key, u0t=u0t, k=k, eps=eps,
                            max_iter=max_iter, kmeans_iters=kmeans_iters,
                            n_total=n, embedding=embedding,
                            qr_every=qr_every,
                            snapshot_iters=snapshot_iters,
                            residual_tol=residual_tol,
                            force_reference=not use_pallas,
                            probe=probe_components and spec.truncated)

    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axes), P(), P()),
        out_specs=(P(),) * 9,
        check_rep=False,
    )(x, kkm, u0t)
    labels, v, emb_full, t_cols, done, status, iso, n_comp, comp = out
    health = HealthReport(col_status=status, isolated_rows=iso,
                          n_components=n_comp, components=comp)
    return make_pic_result(labels, v, t_cols, done, embedding=embedding,
                           embeddings=emb_full, health=health)


@functools.partial(
    jax.jit,
    static_argnames=("k", "mesh", "shard_axes", "max_iter", "kmeans_iters",
                     "affinity_kind", "affinity", "eps_scale", "n_vectors",
                     "use_pallas", "embedding", "qr_every", "snapshot_iters",
                     "residual_tol"),
)
def distributed_gpic_matrix_free(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    mesh: Mesh,
    shard_axes: str | Sequence[str] = "data",
    eps_scale: float = 1e-5,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    affinity: AffinitySpec | None = None,
    n_vectors: int = 1,
    use_pallas: bool = True,
    embedding: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple | None = None,
    residual_tol: float | None = None,
) -> PICResult:
    """Matrix-free distributed GPIC (O2): psum(m r) per step, scales to 1000s
    of nodes. Factorable specs only (cosine kinds, no adaptive scaling or
    truncation; DESIGN.md §2)."""
    axes = _axis_tuple(shard_axes)
    n = x.shape[0]
    eps = eps_scale / n
    spec = as_affinity_spec(affinity, kind=affinity_kind)
    if not spec.factorable:
        raise ValueError(
            f"matrix-free path needs a factorable affinity spec, got {spec}")
    kkm, krand = jax.random.split(key)
    u0t = random_start_vectors(krand, n, n_vectors)

    def fn(x_loc, key, u0t):
        op = _build_sharded_operator(x_loc, axes, None, "matrix_free", spec,
                                     use_pallas=use_pallas)
        # the sweep itself is jnp either way; the flag still governs k-means
        # (factorable specs are never truncated — the probe cannot arm)
        return _run_sharded(op, axes, key=key, u0t=u0t, k=k, eps=eps,
                            max_iter=max_iter, kmeans_iters=kmeans_iters,
                            n_total=n, embedding=embedding,
                            qr_every=qr_every,
                            snapshot_iters=snapshot_iters,
                            residual_tol=residual_tol,
                            force_reference=not use_pallas)

    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axes), P(), P()),
        out_specs=(P(),) * 9,
        check_rep=False,
    )(x, kkm, u0t)
    labels, v, emb_full, t_cols, done, status, iso, n_comp, comp = out
    health = HealthReport(col_status=status, isolated_rows=iso,
                          n_components=n_comp, components=comp)
    return make_pic_result(labels, v, t_cols, done, embedding=embedding,
                           embeddings=emb_full, health=health)


# ---------------------------------------------------------------------------
# Segmented (resumable) execution — the sharded engines in bounded pieces
# ---------------------------------------------------------------------------
#
# The convergence carry threads THROUGH shard_map: the (n_loc, r) leaves
# (v, delta, snaps) stay row-sharded on the mesh between segments, the
# per-column stats replicate, and the supervisor (core/pipeline.py) sees
# one global PowerCarry it can checkpoint. Restoring hands plain host
# arrays back in; shard_map re-lays them out without changing a bit, so
# the resumed trajectory is the uninterrupted one (DESIGN.md §14).


def _carry_specs(axes) -> PowerCarry:
    """PartitionSpecs of the carry pytree: row-block leaves sharded over
    ``axes``, per-column stats replicated."""
    row, rep = P(axes), P()
    return PowerCarry(t=rep, v=row, delta=row, done=rep, t_cols=rep,
                      snaps=row, status=rep, best=rep, since=rep)


_SEG_STATICS = ("mesh", "shard_axes", "eps_scale", "engine", "affinity",
                "a_dtype", "fold_shift", "tile", "use_pallas",
                "block_sparse", "mode", "qr_every", "snapshot_iters",
                "residual_tol", "inject_ring_fault")


@functools.partial(jax.jit, static_argnames=_SEG_STATICS + ("n_vectors",))
def distributed_gpic_segment_start(
    x: jax.Array,
    stop: jax.Array,
    *,
    key: jax.Array,
    mesh: Mesh,
    shard_axes: str | Sequence[str] = "data",
    eps_scale: float = 1e-5,
    engine: str = "explicit",
    affinity: AffinitySpec,
    a_dtype=jnp.float32,
    fold_shift: bool = False,
    tile: int | None = None,
    use_pallas: bool = True,
    block_sparse: bool = True,
    n_vectors: int = 1,
    mode: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple = (),
    residual_tol: float | None = None,
    inject_ring_fault: tuple | None = None,
):
    """Seed the sharded sweep-0 carry (the monolithic seeding: replicated
    random starts sliced per device, degree column normalized by the
    global psum) and run the first bounded segment. ``key`` is the krand
    half of the front door's split. Returns ``(carry, isolated_rows)``."""
    axes = _axis_tuple(shard_axes)
    n = x.shape[0]
    eps = eps_scale / n
    mesh_size = _mesh_size(mesh, axes)
    u0t = random_start_vectors(key, n, n_vectors)

    def fn(x_loc, u0t, stop):
        op = _build_sharded_operator(
            x_loc, axes, mesh_size, engine, affinity, a_dtype=a_dtype,
            fold_shift=fold_shift, tile=tile, use_pallas=use_pallas,
            block_sparse=block_sparse, inject_ring_fault=inject_ring_fault)
        idx = jax.lax.axis_index(axes)
        n_loc = op.degree.shape[0]
        u0t_loc = _local_slice(idx, n_loc, u0t)
        v0_loc = init_power_vectors_local(
            op.degree, u0t_loc, sum_fn=op.sum, dtype=jnp.float32)
        carry = init_power_carry(v0_loc, len(snapshot_iters))
        carry = power_iteration_segment(
            op, carry, eps, stop, mode=mode, qr_every=qr_every,
            snapshot_iters=snapshot_iters, residual_tol=residual_tol)
        iso = count_bad_rows(op.degree, sum_fn=op.sum)
        return carry, iso

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axes), P(), P()),
        out_specs=(_carry_specs(axes), P()),
        check_rep=False,
    )(x, u0t, stop)


@functools.partial(jax.jit, static_argnames=_SEG_STATICS)
def distributed_gpic_segment(
    x: jax.Array,
    carry: PowerCarry,
    stop: jax.Array,
    *,
    mesh: Mesh,
    shard_axes: str | Sequence[str] = "data",
    eps_scale: float = 1e-5,
    engine: str = "explicit",
    affinity: AffinitySpec,
    a_dtype=jnp.float32,
    fold_shift: bool = False,
    tile: int | None = None,
    use_pallas: bool = True,
    block_sparse: bool = True,
    mode: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple = (),
    residual_tol: float | None = None,
    inject_ring_fault: tuple | None = None,
) -> PowerCarry:
    """Advance a (possibly restored) carry by one bounded segment on the
    mesh — the operator is rebuilt inside shard_map from the row-sharded
    features, and the carry's row blocks stay sharded throughout."""
    axes = _axis_tuple(shard_axes)
    eps = eps_scale / x.shape[0]
    mesh_size = _mesh_size(mesh, axes)

    def fn(x_loc, carry_loc, stop):
        op = _build_sharded_operator(
            x_loc, axes, mesh_size, engine, affinity, a_dtype=a_dtype,
            fold_shift=fold_shift, tile=tile, use_pallas=use_pallas,
            block_sparse=block_sparse, inject_ring_fault=inject_ring_fault)
        return power_iteration_segment(
            op, carry_loc, eps, stop, mode=mode, qr_every=qr_every,
            snapshot_iters=snapshot_iters, residual_tol=residual_tol)

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axes), _carry_specs(axes), P()),
        out_specs=_carry_specs(axes),
        check_rep=False,
    )(x, carry, stop)


@functools.partial(jax.jit, static_argnames=(
    "k", "mesh", "shard_axes", "kmeans_iters", "engine", "affinity",
    "a_dtype", "fold_shift", "tile", "use_pallas", "block_sparse",
    "embedding", "snapshot_iters", "probe_components"))
def distributed_gpic_segment_finalize(
    x: jax.Array,
    carry: PowerCarry,
    iso: jax.Array,
    k: int,
    *,
    key: jax.Array,
    mesh: Mesh,
    shard_axes: str | Sequence[str] = "data",
    kmeans_iters: int = 25,
    engine: str = "explicit",
    affinity: AffinitySpec,
    a_dtype=jnp.float32,
    fold_shift: bool = False,
    tile: int | None = None,
    use_pallas: bool = True,
    block_sparse: bool = True,
    embedding: str = "pic",
    snapshot_iters: tuple = (),
    probe_components: bool = True,
) -> PICResult:
    """Close a finished sharded carry into the monolithic run's PICResult:
    the ``_run_sharded`` tail — gather once, standardize, replicated
    k-means (``key`` is the kkm half of the split), the component probe
    when it arms — run inside shard_map with the identical reduction
    bindings."""
    axes = _axis_tuple(shard_axes)
    n = x.shape[0]
    mesh_size = _mesh_size(mesh, axes)
    probe = probe_components and affinity.truncated

    def fn(x_loc, carry_loc, key):
        _, _, gather = mesh_reductions(axes)
        t, v_loc, t_cols, done, snaps_loc, status = finalize_power_carry(
            carry_loc)
        if embedding == "ensemble":
            snaps_loc = backfill_snapshots(snaps_loc, v_loc, t,
                                           snapshot_iters)
            emb_loc = ensemble_embedding(snaps_loc)
        else:
            emb_loc = v_loc
        emb_full = gather(emb_loc)                  # once, after the loop
        v_full = emb_full if emb_loc is v_loc else gather(v_loc)
        emb = standardize_columns(emb_full)
        labels, _ = kmeans(key, emb, k, iters=kmeans_iters,
                           force_reference=not use_pallas)
        if probe:
            op = _build_sharded_operator(
                x_loc, axes, mesh_size, engine, affinity, a_dtype=a_dtype,
                fold_shift=fold_shift, tile=tile, use_pallas=use_pallas,
                block_sparse=block_sparse)
            idx = jax.lax.axis_index(axes)
            n_loc = op.degree.shape[0]
            n_comp, comp_loc = graph_component_probe(
                op, n, row_offset=idx * n_loc)
            comp_full = gather(comp_loc)
        else:
            n_comp = jnp.int32(-1)
            comp_full = jnp.full((n,), -1, jnp.int32)
        return labels, v_full, emb_full, t_cols, done, status, n_comp, \
            comp_full

    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axes), _carry_specs(axes), P()),
        out_specs=(P(),) * 8,
        check_rep=False,
    )(x, carry, key)
    labels, v, emb_full, t_cols, done, status, n_comp, comp = out
    health = HealthReport(col_status=status,
                          isolated_rows=iso.astype(jnp.int32),
                          n_components=n_comp, components=comp)
    return make_pic_result(labels, v, t_cols, done, embedding=embedding,
                           embeddings=emb_full, health=health)


def shard_points(x, mesh: Mesh, shard_axes="data"):
    """Places (n, m) host data row-sharded on the mesh.

    n must divide evenly over the sharded device count (shard_map and the
    streaming ring both need equal row blocks) — trim or pad the input
    first; this raises a clear error instead of an opaque sharding one.
    """
    axes = _axis_tuple(shard_axes)
    x = jnp.asarray(x)
    n_dev = _mesh_size(mesh, axes)
    if x.shape[0] % n_dev:
        raise ValueError(
            f"shard_points: n={x.shape[0]} rows do not divide evenly over "
            f"{n_dev} devices on axes {axes}; pad or trim the input first")
    sharding = NamedSharding(mesh, P(axes))
    return jax.device_put(x, sharding)
