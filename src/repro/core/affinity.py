"""Affinity-matrix construction for Power Iteration Clustering.

The paper (GPIC §4.2) uses cosine similarity between input rows; the affinity
step is the measured bottleneck (88.6 % of serial PIC runtime, Table 1).

Three affinity kinds are provided:

- ``cosine``          raw cosine similarity  (may be negative on signed data)
- ``cosine_shifted``  (1 + cos)/2  — non-negative AND factorable, so the
                      matrix-free path reproduces it exactly (DESIGN.md §2, O2)
- ``rbf``             exp(-||x-y||^2 / (2 sigma^2))

All kinds zero the diagonal (no self-loops), matching the PIC convention.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

AffinityKind = Literal["cosine", "cosine_shifted", "rbf"]


def row_normalize_features(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    """L2-normalize each row (unit-norm embeddings for cosine affinity)."""
    nrm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    return x / jnp.maximum(nrm, eps)


def rbf_bandwidth_heuristic(x: jax.Array, sample: int = 512) -> jax.Array:
    """Median-pairwise-distance bandwidth estimate from a leading sample."""
    s = x[: min(sample, x.shape[0])]
    d2 = (
        jnp.sum(s * s, axis=1)[:, None]
        + jnp.sum(s * s, axis=1)[None, :]
        - 2.0 * s @ s.T
    )
    d2 = jnp.maximum(d2, 0.0)
    med = jnp.median(jnp.sqrt(d2 + jnp.eye(s.shape[0]) * 1e9))
    return jnp.maximum(med, 1e-6)


def _zero_diag(a: jax.Array) -> jax.Array:
    n = a.shape[0]
    return a * (1.0 - jnp.eye(n, dtype=a.dtype))


@functools.partial(jax.jit, static_argnames=("kind",))
def affinity_matrix(
    x: jax.Array,
    kind: AffinityKind = "cosine_shifted",
    sigma: float | jax.Array | None = None,
) -> jax.Array:
    """Dense (n, n) affinity matrix. Pure-jnp reference (oracle for kernels)."""
    if kind in ("cosine", "cosine_shifted"):
        xn = row_normalize_features(x)
        a = xn @ xn.T
        if kind == "cosine_shifted":
            a = 0.5 * (1.0 + a)
        return _zero_diag(a)
    if kind == "rbf":
        sig = rbf_bandwidth_heuristic(x) if sigma is None else jnp.asarray(sigma)
        sq = jnp.sum(x * x, axis=1)
        d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
        a = jnp.exp(-d2 / (2.0 * sig * sig))
        return _zero_diag(a)
    raise ValueError(f"unknown affinity kind {kind!r}")


def affinity_chunked(
    x: jax.Array,
    kind: AffinityKind = "cosine_shifted",
    sigma: float | None = None,
    chunk: int = 4096,
) -> jax.Array:
    """Row-chunked affinity build (the paper's host->device chunking analogue).

    Computes A in row-stripes so the peak temporary is (chunk, n) instead of
    (n, n) intermediates; used by the explicit path when n is large.
    """
    n = x.shape[0]
    if kind in ("cosine", "cosine_shifted"):
        x = row_normalize_features(x)
        xn = x

        def stripe(xc, i0):
            a = xc @ xn.T
            if kind == "cosine_shifted":
                a = 0.5 * (1.0 + a)
            cols = jnp.arange(n)[None, :]
            rows = i0 + jnp.arange(xc.shape[0])[:, None]
            return a * (cols != rows)

    else:
        sig = rbf_bandwidth_heuristic(x) if sigma is None else jnp.asarray(sigma)
        sq = jnp.sum(x * x, axis=1)

        def stripe(xc, i0):
            sqc = jnp.sum(xc * xc, axis=1)
            d2 = jnp.maximum(sqc[:, None] + sq[None, :] - 2.0 * (xc @ x.T), 0.0)
            a = jnp.exp(-d2 / (2.0 * sig * sig))
            cols = jnp.arange(n)[None, :]
            rows = i0 + jnp.arange(xc.shape[0])[:, None]
            return a * (cols != rows)

    stripe = jax.jit(stripe)
    out = []
    for i0 in range(0, n, chunk):
        out.append(stripe(x[i0 : i0 + chunk], i0))
    return jnp.concatenate(out, axis=0)


def matmat_matrix_free(
    xn: jax.Array, v: jax.Array, kind: AffinityKind = "cosine_shifted",
    *, psum=None,
) -> jax.Array:
    """A @ V without materializing A (DESIGN.md §2, optimization O2).

    ``v`` may be a single vector (n,) or a batch of power vectors (n, r) —
    the factored product applies per column, so all r vectors share the two
    O(n·m·r) skinny matmuls (the engine's one-sweep property, DESIGN.md §4).

    For cosine:           A V = X̂ (X̂ᵀ V) − V          (diag of X̂X̂ᵀ is 1)
    For cosine_shifted:   A V = (ΣV · 1 + X̂(X̂ᵀV))/2 − V  (diag is 1 → −1·V)
    Cost O(n·m·r) instead of O(n²·r); exact (same float ops up to
    association). ``xn`` must already be row-normalized.

    ``psum`` finishes the cross-chunk sums when ``xn``/``v`` are the local
    row chunks of a sharded matrix (it closes over the mesh axes; the
    (m, r) block X̂ᵀV and the (r,) column sums ΣV are the ONLY values that
    cross devices — O(m r) per sweep). None means single-chunk (identity).
    The (n_loc, r) skinny product X̂ s is computed exactly once per sweep.
    """
    if psum is None:
        psum = lambda x: x
    if kind == "cosine":
        return xn @ psum(xn.T @ v) - v
    if kind == "cosine_shifted":
        vsum = psum(jnp.sum(v, axis=0))
        return 0.5 * (vsum + xn @ psum(xn.T @ v)) - v
    raise ValueError(f"matrix-free path supports cosine affinities, got {kind!r}")


def matvec_matrix_free(
    xn: jax.Array, v: jax.Array, kind: AffinityKind = "cosine_shifted"
) -> jax.Array:
    """Single-vector alias of ``matmat_matrix_free`` (kept for callers)."""
    return matmat_matrix_free(xn, v, kind)


def degree_matrix_free(
    xn: jax.Array, kind: AffinityKind = "cosine_shifted"
) -> jax.Array:
    """Row sums of A (degree vector) without materializing A."""
    ones = jnp.ones((xn.shape[0],), xn.dtype)
    return matvec_matrix_free(xn, ones, kind)
