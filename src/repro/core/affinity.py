"""Affinity-graph construction for Power Iteration Clustering.

The paper (GPIC §4.2) uses cosine similarity between input rows; the affinity
step is the measured bottleneck (88.6 % of serial PIC runtime, Table 1).

Three affinity kinds are provided:

- ``cosine``          raw cosine similarity  (may be negative on signed data)
- ``cosine_shifted``  (1 + cos)/2  — non-negative AND factorable, so the
                      matrix-free path reproduces it exactly (DESIGN.md §2, O2)
- ``rbf``             exp(-||x-y||^2 / (2 sigma^2))

On top of the kind, :class:`AffinitySpec` selects the *graph construction*
policies (DESIGN.md §11):

- bandwidth: ``'fixed'`` (one global sigma) or ``'adaptive'`` — self-tuning
  local scaling where sigma_i is the distance to the ``scale_k``-th nearest
  neighbor and A_ij = exp(-d_ij^2 / (sigma_i sigma_j)) (Zelnik-Manor &
  Perona style; rbf only).
- truncation: ``knn_k=None`` keeps the dense matrix; an int zeroes every
  row entry below that row's ``knn_k``-th largest similarity (the directed
  kNN graph), which both repairs manifold datasets (two_moons) and cuts
  per-sweep cost at scale.

All kinds zero the diagonal (no self-loops), matching the PIC convention.
This module is pure jnp — the reference semantics. The Pallas realizations
live in kernels/ (two-pass build: kernels/row_topk.py computes the per-row
k-th statistics, the affinity/streaming kernels apply scale + mask in-tile).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Literal

import jax
import jax.numpy as jnp

AffinityKind = Literal["cosine", "cosine_shifted", "rbf"]

AFFINITY_KINDS = ("cosine", "cosine_shifted", "rbf")
BANDWIDTHS = ("fixed", "adaptive")

#: floor for adaptive local scales (duplicated points have a zero k-th
#: neighbor distance; the floor keeps sigma_i * sigma_j away from 0)
SCALE_FLOOR = 1e-6


@dataclass(frozen=True)
class AffinitySpec:
    """Everything that defines the affinity graph, in one hashable value.

    Fields:
      kind:      similarity ('cosine' | 'cosine_shifted' | 'rbf').
      sigma:     global bandwidth (read by 'rbf' with bandwidth='fixed').
      bandwidth: 'fixed' or 'adaptive' (per-row local scaling, rbf only):
                 sigma_i = distance to the scale_k-th nearest neighbor,
                 A_ij = exp(-d_ij^2 / (sigma_i sigma_j)).
      scale_k:   the neighbor rank defining the local scale ('adaptive').
      knn_k:     None = dense; an int truncates each row to entries >= its
                 knn_k-th largest similarity (zeroed in-tile, never stored).

    Instances are frozen + hashable so they ride through ``jax.jit`` static
    arguments; the same spec value drives the single-device kernels, the
    sharded stripe build, and the ppermute ring identically.
    """
    kind: AffinityKind = "cosine_shifted"
    sigma: float = 1.0
    bandwidth: str = "fixed"
    scale_k: int = 7
    knn_k: int | None = None

    def __post_init__(self):
        if self.kind not in AFFINITY_KINDS:
            raise ValueError(
                f"unknown affinity kind {self.kind!r} "
                f"(expected one of {AFFINITY_KINDS})")
        if self.bandwidth not in BANDWIDTHS:
            raise ValueError(
                f"unknown bandwidth policy {self.bandwidth!r} "
                f"(expected one of {BANDWIDTHS})")
        if not float(self.sigma) > 0.0:
            raise ValueError(
                f"sigma must be > 0 (a bandwidth), got {self.sigma}")
        if self.bandwidth == "adaptive":
            if self.kind != "rbf":
                raise ValueError(
                    "bandwidth='adaptive' rescales squared distances "
                    f"(exp(-d^2/(s_i s_j))) — rbf only, got kind={self.kind!r}")
            if int(self.scale_k) < 1:
                raise ValueError(
                    f"scale_k must be >= 1 (a neighbor rank), got {self.scale_k}")
        if self.knn_k is not None and int(self.knn_k) < 1:
            raise ValueError(
                f"knn_k must be >= 1 (a neighbor rank) or None, got {self.knn_k}")

    # -- derived policy flags (read everywhere the spec is threaded) -------

    @property
    def adaptive(self) -> bool:
        return self.bandwidth == "adaptive"

    @property
    def truncated(self) -> bool:
        return self.knn_k is not None

    @property
    def dense_fixed(self) -> bool:
        """True when the spec is the classic PR-2/PR-3 build (no pass 1):
        global bandwidth, no truncation — the bitwise-pinned default path."""
        return not (self.adaptive or self.truncated)

    @property
    def factorable(self) -> bool:
        """True when A V factors as X̂(X̂ᵀV) ± shifts (the O2 matrix-free
        path): cosine kinds only, and only without scaling/truncation."""
        return self.kind in ("cosine", "cosine_shifted") and self.dense_fixed

    def validate_for_n(self, n: int) -> None:
        """Reject neighbor ranks that don't exist among the n-1 off-diagonal
        entries of a row (the [1, n) bound of the front-door contract)."""
        if self.adaptive and not 1 <= int(self.scale_k) < n:
            raise ValueError(
                f"scale_k={self.scale_k} outside [1, n) for n={n} "
                "(each row has n-1 neighbors)")
        if self.truncated and not 1 <= int(self.knn_k) < n:
            raise ValueError(
                f"knn_k={self.knn_k} outside [1, n) for n={n} "
                "(each row has n-1 neighbors)")


def as_affinity_spec(
    spec: AffinitySpec | str | None = None,
    *,
    kind: AffinityKind = "cosine_shifted",
    sigma: float = 1.0,
) -> AffinitySpec:
    """Coerce to an :class:`AffinitySpec`.

    ``spec`` wins when given (an instance passes through; a string is a
    kind); otherwise the legacy ``kind``/``sigma`` kwargs build the dense
    fixed-bandwidth spec they always meant.
    """
    if isinstance(spec, AffinitySpec):
        return spec
    if isinstance(spec, str):
        return AffinitySpec(kind=spec, sigma=sigma)
    if spec is not None:
        raise TypeError(
            f"spec must be an AffinitySpec, a kind string, or None; "
            f"got {type(spec).__name__}")
    return AffinitySpec(kind=kind, sigma=sigma)


def row_normalize_features(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    """L2-normalize each row (unit-norm embeddings for cosine affinity)."""
    nrm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    return x / jnp.maximum(nrm, eps)


def rbf_bandwidth_heuristic(x: jax.Array, sample: int = 512) -> jax.Array:
    """Median-pairwise-distance bandwidth estimate from a STRIDED sample.

    A leading slice (``x[:sample]``) is badly biased on sorted or
    cluster-ordered inputs — every synthetic generator in data/synthetic.py
    emits points class-by-class, so the first 512 rows can all lie in one
    cluster and the median collapses to the intra-cluster distance. The
    strided sample touches every region of the input regardless of row
    order (regression-tested in tests/test_affinity_spec.py).
    """
    n = x.shape[0]
    take = min(sample, n)
    # ceil-division stride: floor would degenerate to the leading slice
    # for sample < n < 2*sample and drop the tail whenever n/take is
    # non-integral — the stride must span the WHOLE row range
    s = x[:: max(-(-n // take), 1)][:take]
    d2 = (
        jnp.sum(s * s, axis=1)[:, None]
        + jnp.sum(s * s, axis=1)[None, :]
        - 2.0 * s @ s.T
    )
    d2 = jnp.maximum(d2, 0.0)
    med = jnp.median(jnp.sqrt(d2 + jnp.eye(s.shape[0]) * 1e9))
    return jnp.maximum(med, 1e-6)


def _zero_diag(a: jax.Array) -> jax.Array:
    n = a.shape[0]
    return a * (1.0 - jnp.eye(n, dtype=a.dtype))


def pairwise_sq_dists(x: jax.Array, xc: jax.Array | None = None) -> jax.Array:
    """Dense (R, C) squared euclidean distances (clamped at 0)."""
    c = x if xc is None else xc
    sqr = jnp.sum(x * x, axis=1)
    sqc = jnp.sum(c * c, axis=1)
    return jnp.maximum(sqr[:, None] + sqc[None, :] - 2.0 * (x @ c.T), 0.0)


def local_scales(x: jax.Array, scale_k: int) -> jax.Array:
    """Per-row adaptive bandwidth: sigma_i = ||x_i - x_(scale_k)|| — the
    distance to the scale_k-th nearest neighbor (self excluded), floored at
    ``SCALE_FLOOR``. Dense jnp reference for the streamed two-pass build."""
    n = x.shape[0]
    d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, pairwise_sq_dists(x))
    kth = -jax.lax.top_k(-d2, scale_k)[0][:, -1]          # k-th smallest d2
    return jnp.maximum(jnp.sqrt(kth), SCALE_FLOOR)


def knn_thresholds(a: jax.Array, knn_k: int) -> jax.Array:
    """Per-row truncation threshold: the knn_k-th largest off-diagonal
    similarity of each row of the (already diagonal-zeroed) dense A."""
    n = a.shape[0]
    masked = jnp.where(jnp.eye(n, dtype=bool), -jnp.inf, a)
    return jax.lax.top_k(masked, knn_k)[0][:, -1]


@functools.partial(jax.jit, static_argnames=("kind", "spec"))
def affinity_matrix(
    x: jax.Array,
    kind: AffinityKind = "cosine_shifted",
    sigma: float | jax.Array | None = None,
    *,
    spec: AffinitySpec | None = None,
) -> jax.Array:
    """Dense (n, n) affinity matrix. Pure-jnp reference (oracle for kernels).

    ``spec`` selects the full graph-construction policy (adaptive local
    scaling, kNN truncation); the legacy ``kind``/``sigma`` arguments cover
    the dense fixed-bandwidth builds (``sigma=None`` on 'rbf' applies the
    strided median heuristic — a data-dependent value the hashable spec
    deliberately does not model).
    """
    if spec is not None:
        spec.validate_for_n(x.shape[0])
        if spec.kind in ("cosine", "cosine_shifted"):
            xn = row_normalize_features(x)
            a = xn @ xn.T
            if spec.kind == "cosine_shifted":
                a = 0.5 * (1.0 + a)
        elif spec.adaptive:
            scl = local_scales(x, spec.scale_k)
            a = jnp.exp(-pairwise_sq_dists(x) / (scl[:, None] * scl[None, :]))
        else:
            a = jnp.exp(-pairwise_sq_dists(x)
                        / (2.0 * spec.sigma * spec.sigma))
        a = _zero_diag(a)
        if spec.truncated:
            thr = knn_thresholds(a, spec.knn_k)
            a = jnp.where(a >= thr[:, None], a, 0.0)
            a = _zero_diag(a)
        return a

    if kind in ("cosine", "cosine_shifted"):
        xn = row_normalize_features(x)
        a = xn @ xn.T
        if kind == "cosine_shifted":
            a = 0.5 * (1.0 + a)
        return _zero_diag(a)
    if kind == "rbf":
        sig = rbf_bandwidth_heuristic(x) if sigma is None else jnp.asarray(sigma)
        sq = jnp.sum(x * x, axis=1)
        d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
        a = jnp.exp(-d2 / (2.0 * sig * sig))
        return _zero_diag(a)
    raise ValueError(f"unknown affinity kind {kind!r}")


def affinity_chunked(
    x: jax.Array,
    kind: AffinityKind = "cosine_shifted",
    sigma: float | None = None,
    chunk: int = 4096,
) -> jax.Array:
    """Row-chunked affinity build (the paper's host->device chunking analogue).

    Computes A in row-stripes so the peak temporary is (chunk, n) instead of
    (n, n) intermediates; used by the explicit path when n is large.
    """
    n = x.shape[0]
    if kind in ("cosine", "cosine_shifted"):
        x = row_normalize_features(x)
        xn = x

        def stripe(xc, i0):
            a = xc @ xn.T
            if kind == "cosine_shifted":
                a = 0.5 * (1.0 + a)
            cols = jnp.arange(n)[None, :]
            rows = i0 + jnp.arange(xc.shape[0])[:, None]
            return a * (cols != rows)

    else:
        sig = rbf_bandwidth_heuristic(x) if sigma is None else jnp.asarray(sigma)
        sq = jnp.sum(x * x, axis=1)

        def stripe(xc, i0):
            sqc = jnp.sum(xc * xc, axis=1)
            d2 = jnp.maximum(sqc[:, None] + sq[None, :] - 2.0 * (xc @ x.T), 0.0)
            a = jnp.exp(-d2 / (2.0 * sig * sig))
            cols = jnp.arange(n)[None, :]
            rows = i0 + jnp.arange(xc.shape[0])[:, None]
            return a * (cols != rows)

    stripe = jax.jit(stripe)
    out = []
    for i0 in range(0, n, chunk):
        out.append(stripe(x[i0 : i0 + chunk], i0))
    return jnp.concatenate(out, axis=0)


def matmat_matrix_free(
    xn: jax.Array, v: jax.Array,
    kind: AffinityKind | AffinitySpec = "cosine_shifted",
    *, psum=None,
) -> jax.Array:
    """A @ V without materializing A (DESIGN.md §2, optimization O2).

    ``v`` may be a single vector (n,) or a batch of power vectors (n, r) —
    the factored product applies per column, so all r vectors share the two
    O(n·m·r) skinny matmuls (the engine's one-sweep property, DESIGN.md §4).

    For cosine:           A V = X̂ (X̂ᵀ V) − V          (diag of X̂X̂ᵀ is 1)
    For cosine_shifted:   A V = (ΣV · 1 + X̂(X̂ᵀV))/2 − V  (diag is 1 → −1·V)
    Cost O(n·m·r) instead of O(n²·r); exact (same float ops up to
    association). ``xn`` must already be row-normalized.

    ``kind`` may be an :class:`AffinitySpec`; only factorable specs are
    accepted (adaptive scaling and kNN truncation destroy the low-rank ±
    diagonal structure the factorization rests on).

    ``psum`` finishes the cross-chunk sums when ``xn``/``v`` are the local
    row chunks of a sharded matrix (it closes over the mesh axes; the
    (m, r) block X̂ᵀV and the (r,) column sums ΣV are the ONLY values that
    cross devices — O(m r) per sweep). None means single-chunk (identity).
    The (n_loc, r) skinny product X̂ s is computed exactly once per sweep.
    """
    if isinstance(kind, AffinitySpec):
        if not kind.factorable:
            raise ValueError(
                "matrix-free path needs a factorable spec (cosine kinds, "
                f"fixed bandwidth, no truncation); got {kind}")
        kind = kind.kind
    if psum is None:
        psum = lambda x: x
    if kind == "cosine":
        return xn @ psum(xn.T @ v) - v
    if kind == "cosine_shifted":
        vsum = psum(jnp.sum(v, axis=0))
        return 0.5 * (vsum + xn @ psum(xn.T @ v)) - v
    raise ValueError(f"matrix-free path supports cosine affinities, got {kind!r}")


def matvec_matrix_free(
    xn: jax.Array, v: jax.Array,
    kind: AffinityKind | AffinitySpec = "cosine_shifted",
) -> jax.Array:
    """Single-vector alias of ``matmat_matrix_free`` (kept for callers)."""
    return matmat_matrix_free(xn, v, kind)


def degree_matrix_free(
    xn: jax.Array, kind: AffinityKind | AffinitySpec = "cosine_shifted"
) -> jax.Array:
    """Row sums of A (degree vector) without materializing A."""
    ones = jnp.ones((xn.shape[0],), xn.dtype)
    return matvec_matrix_free(xn, ones, kind)


# ---------------------------------------------------------------------------
# Block-index planning for truncated specs (DESIGN.md §13)
# ---------------------------------------------------------------------------

def block_plan(live: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(counts, col_idx, max_b) block-CSR plan from an (nI, nJ) live map.

    ``live`` is boolean/int: live[i, j] != 0 iff column-block j of row-block
    i holds at least one surviving affinity entry. The plan is the
    scalar-prefetch operand set of the kernels/block_sparse.py sweeps:

      counts[i]        number of live column-blocks in row-block i
      col_idx[i, :]    the live block ids in ASCENDING order first — the
                       sweep accumulates blocks in the same order the dense
                       grid visits them, which is what keeps the two paths
                       bitwise-equal; the tail holds the dead ids (any
                       valid in-range index works, skipped steps only
                       prefetch) in ascending order too
      max_b            max(counts) clamped to >= 1 — the traced grid extent

    Everything is traced (jit-safe); only the (nI, nJ) SHAPE is static.

    The stable partition is built from prefix sums (live id j lands at slot
    cumsum(live)[j]-1, dead id j at counts + cumsum(dead)[j]-1), NOT from
    ``argsort(~live, stable=True)``, although the two are value-identical:
    on jax 0.4.x CPU, a sort whose output feeds the scalar-prefetch index
    maps of an interpret-mode kernel inside ``shard_map`` miscompiles — the
    gathered ids silently degrade to the identity, which reads dead (zero)
    stripe tiles on every device whose live blocks are off-diagonal and
    collapses the power iteration onto one component (DESIGN.md §13).
    """
    live = jnp.asarray(live) != 0
    n_i, n_j = live.shape
    counts = jnp.sum(live, axis=1).astype(jnp.int32)
    csum = jnp.cumsum(live.astype(jnp.int32), axis=1)
    ids = jnp.arange(n_j, dtype=jnp.int32)[None, :]
    slot = jnp.where(live, csum - 1, counts[:, None] + ids - csum)
    col_idx = (jnp.zeros((n_i, n_j), jnp.int32)
               .at[jnp.arange(n_i)[:, None], slot]
               .set(jnp.broadcast_to(ids, (n_i, n_j))))
    max_b = jnp.maximum(jnp.max(counts), 1).astype(jnp.int32)
    return counts, col_idx, max_b


def plan_to_live(counts: jax.Array, col_idx: jax.Array) -> jax.Array:
    """Invert a block plan back to its (nI, nJ) boolean live map — the
    property-test oracle: scattering True through the first counts[i]
    entries of col_idx[i] must reproduce the map the plan came from. The
    scatter uses ``.max`` (not ``.set``) because the padded tail repeats
    dead ids with False and must not clobber a live True."""
    n_i, n_j = col_idx.shape
    slot_live = jnp.arange(n_j)[None, :] < counts[:, None]
    live = jnp.zeros((n_i, n_j), bool)
    return live.at[jnp.arange(n_i)[:, None], col_idx].max(slot_live)


def dense_block_live(a: jax.Array, tm: int, tn: int) -> jax.Array:
    """(nI, nJ) live map of a STORED truncated matrix on the (tm, tn) tile
    grid (rows/cols zero-padded up to tile multiples, so padding blocks are
    dead). The explicit engines plan from the matrix they just built;
    streaming engines use kernels/block_sparse.block_liveness instead."""
    n_rows, n_cols = a.shape
    rp = -(-n_rows // tm) * tm
    cp = -(-n_cols // tn) * tn
    ap = jnp.pad(a, ((0, rp - n_rows), (0, cp - n_cols)))
    tiles = ap.reshape(rp // tm, tm, cp // tn, tn)
    return jnp.any(tiles != 0, axis=(1, 3))
