"""The GPIC front door: one config dataclass, one entry point.

Every scenario the repo supports — local or sharded, explicit / streaming /
matrix-free, any affinity kind, any number of power vectors — is a field
combination on :class:`GPICConfig`; :func:`run_gpic` routes it to the right
operator-backed entry point. Examples, benchmarks, and launch/ call this
instead of hand-assembling keyword lists against five functions.

    from repro.core import GPICConfig, run_gpic

    # single device, paper-faithful
    res = run_gpic(x, k=4, config=GPICConfig(affinity_kind="rbf", sigma=0.3))

    # production config: sharded A-free streaming on a mesh
    cfg = GPICConfig(engine="streaming", mesh=mesh, shard_axes="data",
                     affinity_kind="rbf", sigma=0.3, n_vectors=4)
    res = run_gpic(shard_points(x, mesh), k=4, config=cfg)

Routing table (operator names from core/operators.py):

    mesh   engine        entry point                    operator
    ------ ------------- ------------------------------ ---------------------------
    None   explicit      gpic(engine='explicit')        explicit_operator
    None   streaming     gpic(engine='streaming')       streaming_operator
    None   matrix_free   gpic_matrix_free               matrix_free_operator
    set    explicit      distributed_gpic               sharded_explicit_operator
    set    streaming     distributed_gpic('streaming')  sharded_streaming_operator
    set    matrix_free   distributed_gpic_matrix_free   sharded_matrix_free_operator
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, replace
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..kernels import ops
from .affinity import AffinityKind, AffinitySpec, as_affinity_spec
from .distributed import (
    distributed_gpic,
    distributed_gpic_matrix_free,
    distributed_gpic_segment,
    distributed_gpic_segment_finalize,
    distributed_gpic_segment_start,
)
from .gpic import (
    gpic,
    gpic_matrix_free,
    gpic_segment,
    gpic_segment_finalize,
    gpic_segment_start,
)
from .health import (
    GPICError,
    StragglerTimeout,
    raise_for_health,
    validate_features,
)
from .pic import PICResult
from .power import EMBEDDINGS, default_snapshot_iters, power_carry_like

ENGINES = ("explicit", "streaming", "matrix_free")


@dataclass(frozen=True)
class GPICConfig:
    """Everything that selects and tunes a GPIC run, in one hashable value.

    Engine / placement:
      engine:       'explicit' (paper-faithful A build), 'streaming'
                    (A-free tile regeneration), or 'matrix_free' (factored
                    jnp product, cosine kinds only).
      mesh:         None → single device; a Mesh → sharded via shard_map
                    (pass row-sharded x, e.g. from ``shard_points``).
      shard_axes:   mesh axis name(s) the rows stripe over.

    Clustering:
      affinity:     an :class:`AffinitySpec` — the full graph-construction
                    policy (kind, bandwidth: fixed sigma or adaptive local
                    scaling, kNN truncation; DESIGN.md §11). None derives
                    the dense fixed spec from affinity_kind/sigma.
      affinity_kind/sigma: legacy shorthand for the dense fixed spec
                    (sigma only read for 'rbf'); rejected alongside a
                    non-None ``affinity`` so the two routes cannot
                    silently disagree.
      n_vectors:    r power vectors in one engine state (O3).
      embedding:    'pic' (classic per-column loop), 'orthogonal' (block
                    iteration: column 0 pinned to the classic trajectory,
                    columns 1..r-1 QR-orthonormalized into the invariant
                    subspace — the nested-structure fix, DESIGN.md §10),
                    or 'ensemble' (diffusion-time snapshot concatenation).
      qr_every:     re-orthonormalization period in sweeps ('orthogonal').
      residual_tol: arm the subspace residual stopping rule ('orthogonal'
                    with n_vectors > 1): once column 0 converges
                    classically, a relative ||WV − VΛ|| residual below
                    this on a QR step stops the whole block instead of
                    running to max_iter (DESIGN.md §11). None = off (the
                    bitwise PR-3 loop).
      snapshot_iters: ascending iteration counts to snapshot ('ensemble';
                    None = geometric in max_iter).
      eps_scale:    convergence threshold numerator (eps = eps_scale / n).
      max_iter / kmeans_iters: loop caps.

    Performance:
      a_dtype:      A-stripe storage dtype ('explicit' engines; bf16 = O4).
      fold_shift:   O5 — fold the cosine_shifted transform out of the
                    O(n²/P) build (sharded explicit engine only).
      tile:         Pallas tile edge override (None = static autotuner).
      block_sparse: route truncated (kNN) specs through the fused one-pass
                    build and the block-CSR sweeps, so sweep traffic
                    tracks nnz instead of n² (DESIGN.md §13). False keeps
                    the dense-storage two-pass path — bitwise-equal
                    results, the comparison baseline. No effect on dense
                    specs or the matrix-free engine.
      use_pallas:   False routes every op to the jnp reference oracles.
      seed:         key for k-means init + extra power vectors when
                    ``run_gpic`` isn't handed an explicit key.

    Robustness (DESIGN.md §12):
      sanitize:     zero-fill non-finite feature values at the front door
                    (recorded in ``PICResult.health.notes``) instead of
                    raising :class:`~repro.core.health.NonFiniteInputError`.
      component_probe: run the on-device disconnected-component check on
                    truncated (kNN) graphs; the count lands in
                    ``PICResult.health.n_components``. False skips the
                    probe's extra sweeps.
      retry_on_fallback: when a kernel falls back to its reference oracle
                    MID-RUN (``kernel_fallback:<op>`` would be noted), the
                    trajectory mixes kernel and reference ops. True
                    re-runs the whole pipeline on the reference oracles
                    (``use_pallas=False``) for a CONSISTENT trajectory;
                    the note upgrades to ``kernel_fallback_retried:<op>``.
                    Under the supervisor (``checkpoint_every``) it upgrades
                    further: the tainted segment is discarded and the run
                    resumes from the last snapshot on the oracles
                    (``kernel_fallback_resumed:<op>``).

    Resumable execution (the PR-9 supervisor, DESIGN.md §14):
      checkpoint_every: run the power loop in bounded segments of this many
                    sweeps, snapshotting the full convergence carry after
                    each through ``train/checkpoint.py``. The segment
                    boundary only moves where the while_loop STOPS — every
                    sweep's arithmetic is the monolithic loop's, so a run
                    interrupted at any sweep and resumed is bitwise
                    identical to the uninterrupted run. Set together with
                    ckpt_dir (both or neither).
      ckpt_dir:     snapshot directory. If it already holds a valid
                    snapshot (a previous attempt died), the run resumes
                    from it (``resumed:<sweep>`` note) instead of
                    restarting at sweep 0. Corrupt snapshots (checksum
                    mismatch, truncated leaves) are quarantined and the
                    supervisor falls back to the previous valid step
                    (``checkpoint_skipped:<dir>``).
      max_retries:  attempts the supervisor may restart after a retryable
                    failure (typed GPICError, injected fault, straggler
                    timeout) before re-raising. Each retry resumes from
                    the last snapshot and is recorded as
                    ``retry:<n>:<ErrorClass>``.
      backoff:      base seconds for exponential backoff between retries
                    (sleep = backoff · 2^(attempt-1); 0 = immediate).
      straggler_timeout: wall-clock budget per segment in seconds; a
                    segment exceeding it raises
                    :class:`~repro.core.health.StragglerTimeout` (noted
                    ``straggler:<sweep>:<sec>``), which the retry loop
                    treats like any other retryable fault. Works without
                    checkpointing (the whole run is then one segment).
      inject_ring_fault: fault-injection hook forwarded to the sharded
                    streaming engine — ('ring_nan', stage) poisons that
                    ring stage's consumed block with NaN (requires mesh +
                    engine='streaming'; tests/test_resume.py).
    """
    engine: str = "explicit"
    mesh: Mesh | None = None
    shard_axes: str | Sequence[str] = "data"
    affinity: AffinitySpec | None = None
    affinity_kind: AffinityKind = "cosine_shifted"
    sigma: float = 1.0
    n_vectors: int = 1
    embedding: str = "pic"
    qr_every: int = 1
    residual_tol: float | None = None
    snapshot_iters: Sequence[int] | None = None
    eps_scale: float = 1e-5
    max_iter: int = 50
    kmeans_iters: int = 25
    a_dtype: Any = jnp.float32
    fold_shift: bool = False
    tile: int | None = None
    block_sparse: bool = True
    use_pallas: bool = True
    seed: int = 0
    sanitize: bool = False
    component_probe: bool = True
    retry_on_fallback: bool = False
    checkpoint_every: int | None = None
    ckpt_dir: str | None = None
    max_retries: int = 3
    backoff: float = 0.0
    straggler_timeout: float | None = None
    inject_ring_fault: tuple | None = None

    def with_(self, **updates) -> "GPICConfig":
        """Functional update (``dataclasses.replace`` with a shorter name)."""
        return replace(self, **updates)


def run_gpic(
    x: jax.Array,
    k: int,
    config: GPICConfig | None = None,
    *,
    key: jax.Array | None = None,
    segment_injector: Callable[[int], None] | None = None,
    **overrides,
) -> PICResult:
    """Run GPIC as described by ``config`` (plus keyword overrides).

    ``x`` is the (n, m) feature matrix — row-sharded on ``config.mesh``
    for distributed runs (see ``shard_points``), a plain array otherwise.
    Returns the extended :class:`PICResult` (full (n, r) embedding,
    per-column iteration stats, and the populated ``health`` report).

    Robustness contract (DESIGN.md §12): degenerate inputs raise a typed
    :class:`~repro.core.health.GPICError` subclass at the front door
    (non-finite features unless ``sanitize``, n < k, constant rows) or
    after the run (every row isolated, every power column dead); anything
    less total returns normally with the damage described in
    ``result.health`` — never silent garbage.

    ``segment_injector`` is the fault-injection hook of the supervised
    (resumable) path: a callable invoked with the current sweep count at
    every segment boundary, free to raise (e.g.
    ``FailureInjector.maybe_fail``) — the supervisor classifies the raise
    as retryable and resumes from the last snapshot. Passing it (or
    setting ``checkpoint_every`` / ``straggler_timeout``) routes the run
    through the segmented engines; the trajectory stays bitwise identical
    to the monolithic path (DESIGN.md §14).
    """
    cfg = config or GPICConfig()
    if overrides:
        cfg = cfg.with_(**overrides)
    if cfg.engine not in ENGINES:
        raise ValueError(
            f"unknown engine {cfg.engine!r} (expected one of {ENGINES})")
    if cfg.embedding not in EMBEDDINGS:
        raise ValueError(
            f"unknown embedding {cfg.embedding!r} "
            f"(expected one of {EMBEDDINGS})")
    if cfg.qr_every < 1:
        raise ValueError(
            f"qr_every must be >= 1 (a period in sweeps), got {cfg.qr_every}")
    if cfg.qr_every != 1 and cfg.embedding != "orthogonal":
        raise ValueError(
            "qr_every tunes the re-orthonormalization period of "
            "embedding='orthogonal' only")
    if cfg.snapshot_iters is not None and cfg.embedding != "ensemble":
        raise ValueError(
            "snapshot_iters selects the diffusion times of "
            "embedding='ensemble' only")
    if cfg.residual_tol is not None:
        if cfg.embedding != "orthogonal":
            raise ValueError(
                "residual_tol arms the subspace residual stopping rule of "
                "embedding='orthogonal' only")
        if cfg.n_vectors < 2:
            raise ValueError(
                "residual_tol stops the QR-coupled block columns; with "
                "n_vectors=1 the orthogonal loop IS the classic one and "
                "the rule can never arm — drop it or raise n_vectors")
        if not float(cfg.residual_tol) > 0.0:
            raise ValueError(
                f"residual_tol must be > 0 (a relative residual), got "
                f"{cfg.residual_tol}")
    # resolve the affinity spec: an explicit AffinitySpec wins; setting it
    # ALONGSIDE non-default legacy shorthand is ambiguous and rejected
    # (sigma <= 0 and bad bandwidth/kind combos are rejected by the spec's
    # own constructor; neighbor-rank bounds need n and are checked here)
    if cfg.affinity is not None and (
            cfg.affinity_kind != "cosine_shifted" or cfg.sigma != 1.0):
        raise ValueError(
            "set either GPICConfig.affinity (the full spec) or the legacy "
            "affinity_kind/sigma shorthand, not both")
    spec = as_affinity_spec(cfg.affinity, kind=cfg.affinity_kind,
                            sigma=cfg.sigma)
    spec.validate_for_n(x.shape[0])
    # reject field combinations the selected route would silently ignore —
    # the front door must not mask misconfiguration a direct call rejects
    if cfg.engine == "matrix_free":
        dropped = [name for name, bad in (
            ("fold_shift", cfg.fold_shift),
            ("tile", cfg.tile is not None),
            ("a_dtype", cfg.a_dtype != jnp.float32),
        ) if bad]
        if dropped:
            raise ValueError(
                f"engine='matrix_free' does not use {dropped} (the factored "
                "jnp sweep has no A storage or Pallas tiles)")
        if not spec.factorable:
            raise ValueError(
                "engine='matrix_free' needs a factorable affinity spec "
                "(cosine kinds, fixed bandwidth, no truncation); got "
                f"{spec} — use the explicit or streaming engine for "
                "adaptive/kNN graphs")
    elif cfg.fold_shift and (cfg.mesh is None or cfg.engine != "explicit"
                             or spec.kind != "cosine_shifted"
                             or not spec.dense_fixed):
        raise ValueError(
            "fold_shift (O5) applies only to the sharded explicit engine "
            "with a dense fixed cosine_shifted spec (the shift being "
            "folded has no closed form on a truncated row)")
    if cfg.engine == "streaming" and cfg.a_dtype != jnp.float32:
        raise ValueError(
            "a_dtype (O4) selects the A *storage* dtype; the streaming "
            "engine never stores A")
    if (cfg.checkpoint_every is None) != (cfg.ckpt_dir is None):
        raise ValueError(
            "checkpoint_every and ckpt_dir come as a pair (a snapshot "
            "cadence needs a directory and vice versa); set both or "
            "neither")
    if cfg.checkpoint_every is not None and cfg.checkpoint_every < 1:
        raise ValueError(
            f"checkpoint_every must be >= 1 (a period in sweeps), got "
            f"{cfg.checkpoint_every}")
    if cfg.max_retries < 0:
        raise ValueError(f"max_retries must be >= 0, got {cfg.max_retries}")
    if cfg.backoff < 0:
        raise ValueError(f"backoff must be >= 0 seconds, got {cfg.backoff}")
    if cfg.straggler_timeout is not None and not cfg.straggler_timeout > 0:
        raise ValueError(
            f"straggler_timeout must be > 0 seconds, got "
            f"{cfg.straggler_timeout}")
    if cfg.inject_ring_fault is not None and (
            cfg.mesh is None or cfg.engine != "streaming"):
        raise ValueError(
            "inject_ring_fault poisons a sharded streaming ring stage; it "
            "needs mesh set and engine='streaming'")
    if key is None:
        key = jax.random.key(cfg.seed)

    # front-door input validation (typed errors; value checks skip under
    # a tracer and the device-side latches carry the load)
    x, health_notes = validate_features(x, k, sanitize=cfg.sanitize)
    fallbacks_before = ops.kernel_fallbacks()

    snapshot_iters = (None if cfg.snapshot_iters is None
                      else tuple(cfg.snapshot_iters))
    common = dict(key=key, max_iter=cfg.max_iter,
                  kmeans_iters=cfg.kmeans_iters,
                  affinity=spec, n_vectors=cfg.n_vectors,
                  embedding=cfg.embedding, qr_every=cfg.qr_every,
                  snapshot_iters=snapshot_iters,
                  residual_tol=cfg.residual_tol)

    def _route(c: GPICConfig) -> PICResult:
        if c.mesh is None:
            if c.engine == "matrix_free":
                return gpic_matrix_free(x, k, eps=c.eps_scale / x.shape[0],
                                        use_pallas=c.use_pallas, **common)
            return gpic(
                x, k, engine=c.engine, a_dtype=c.a_dtype,
                tile=c.tile, use_pallas=c.use_pallas,
                block_sparse=c.block_sparse,
                eps=c.eps_scale / x.shape[0],
                probe_components=c.component_probe, **common)
        shard_axes = (c.shard_axes if isinstance(c.shard_axes, str)
                      else tuple(c.shard_axes))
        if c.engine == "matrix_free":
            return distributed_gpic_matrix_free(
                x, k, mesh=c.mesh, shard_axes=shard_axes,
                eps_scale=c.eps_scale, use_pallas=c.use_pallas, **common)
        return distributed_gpic(
            x, k, mesh=c.mesh, shard_axes=shard_axes,
            engine=c.engine, eps_scale=c.eps_scale,
            a_dtype=c.a_dtype, fold_shift=c.fold_shift,
            tile=c.tile, use_pallas=c.use_pallas,
            block_sparse=c.block_sparse,
            probe_components=c.component_probe,
            inject_ring_fault=c.inject_ring_fault, **common)

    supervised = (cfg.checkpoint_every is not None
                  or cfg.straggler_timeout is not None
                  or segment_injector is not None)
    if supervised:
        # the resumable path handles fallback classification itself (it
        # must not save a kernel/reference-mixed segment)
        res, sup_notes = _run_supervised(
            x, k, cfg, key=key, spec=spec,
            segment_injector=segment_injector)
        notes = tuple(health_notes) + sup_notes
    else:
        res = _route(cfg)
        # attach host-side events (kernel fallbacks that first fired
        # during this run)
        new_fallback_ops = tuple(sorted(
            op for op in ops.kernel_fallbacks()
            if op not in fallbacks_before))
        note_tag = "kernel_fallback"
        if new_fallback_ops and cfg.retry_on_fallback and cfg.use_pallas:
            # a mid-run fallback leaves a MIXED kernel/reference trajectory
            # (only the ops that failed were served by their oracles);
            # re-run the whole pipeline on the reference oracles so every
            # sweep of the reported result came from ONE consistent
            # implementation
            res = _route(cfg.with_(use_pallas=False))
            note_tag = "kernel_fallback_retried"
        notes = tuple(health_notes) + tuple(
            f"{note_tag}:{op}" for op in new_fallback_ops)
    if res.health is not None and notes:
        res = replace(res, health=replace(
            res.health, notes=res.health.notes + notes))
    if res.health is not None:
        raise_for_health(res.health, x.shape[0])
    return res


def _segment_plan(cfg: GPICConfig):
    """Resolve the loop-mode arguments of the segmented engines so the
    segment trajectory IS the monolithic one: 'ensemble' is the classic
    'pic' loop with a snapshot schedule (resolved here to the same default
    geometric schedule ``ensemble_power_iteration`` derives, with the same
    validation), the other embeddings pass through unchanged.

    Returns (mode, qr_every, snapshot_iters, residual_tol).
    """
    if cfg.embedding != "ensemble":
        return cfg.embedding, cfg.qr_every, (), cfg.residual_tol
    si = tuple(int(s) for s in (
        cfg.snapshot_iters if cfg.snapshot_iters is not None
        else default_snapshot_iters(cfg.max_iter)))
    if not si or list(si) != sorted(set(si)):
        raise ValueError(
            f"snapshot_iters must be non-empty strictly ascending ints, "
            f"got {si!r}")
    if si[0] < 1 or si[-1] > cfg.max_iter:
        raise ValueError(
            f"snapshot_iters {si!r} must lie in [1, max_iter="
            f"{cfg.max_iter}]")
    return "pic", 1, si, None


class _FallbackResume(Exception):
    """Internal control flow: a segment first tripped a kernel fallback
    under ``retry_on_fallback`` — the segment is tainted (mixed kernel /
    reference sweeps), so it is discarded unsaved and the run resumes from
    the last snapshot on the reference oracles."""

    def __init__(self, fallback_ops):
        super().__init__(f"kernel fallback mid-segment: {fallback_ops}")
        self.fallback_ops = fallback_ops


def _run_supervised(x, k, cfg: GPICConfig, *, key, spec, segment_injector):
    """The resumable-execution supervisor (DESIGN.md §14).

    Runs the power loop in bounded segments through the segmented engine
    entry points, snapshotting the convergence carry after each segment,
    and classifies failures into retry-with-resume: a typed
    :class:`~repro.core.health.GPICError` (divergence, straggler timeout,
    injected fault) restarts the attempt from the newest valid snapshot
    with exponential backoff; a first kernel fallback under
    ``retry_on_fallback`` discards the tainted segment and resumes on the
    reference oracles. Because segmentation only moves where the
    while_loop STOPS, every completed sweep is the monolithic loop's —
    resumed runs are bitwise identical to uninterrupted ones.

    Returns (result, notes): the PICResult plus the supervisor's note
    history (``resumed:<sweep>``, ``retry:<n>:<ErrorClass>``,
    ``checkpoint_skipped:<dir>``, ``straggler:<sweep>:<sec>``,
    ``kernel_fallback[_resumed]:<op>``).
    """
    # train imports core at module load; import lazily to avoid the cycle
    from ..train import checkpoint as ckpt
    from ..train.fault_tolerance import StragglerMonitor

    n = x.shape[0]
    mode, qr_every, si, residual_tol = _segment_plan(cfg)
    ce = cfg.checkpoint_every or cfg.max_iter
    kkm, krand = jax.random.split(key)
    local = cfg.mesh is None
    shard_axes = (cfg.shard_axes if isinstance(cfg.shard_axes, str)
                  else tuple(cfg.shard_axes))
    saver = ckpt.AsyncCheckpointer() if cfg.ckpt_dir is not None else None
    monitor = StragglerMonitor()
    notes: list[str] = []

    def seg_kwargs(use_pallas):
        kw = dict(affinity=spec, engine=cfg.engine, a_dtype=cfg.a_dtype,
                  tile=cfg.tile, use_pallas=use_pallas,
                  block_sparse=cfg.block_sparse, mode=mode,
                  qr_every=qr_every, snapshot_iters=si,
                  residual_tol=residual_tol)
        if local:
            kw["eps"] = cfg.eps_scale / n
        else:
            kw.update(mesh=cfg.mesh, shard_axes=shard_axes,
                      eps_scale=cfg.eps_scale, fold_shift=cfg.fold_shift,
                      inject_ring_fault=cfg.inject_ring_fault)
        return kw

    def fin_kwargs(use_pallas):
        kw = dict(kmeans_iters=cfg.kmeans_iters, affinity=spec,
                  engine=cfg.engine, a_dtype=cfg.a_dtype, tile=cfg.tile,
                  use_pallas=use_pallas, block_sparse=cfg.block_sparse,
                  embedding=cfg.embedding, snapshot_iters=si,
                  probe_components=cfg.component_probe)
        if not local:
            kw.update(mesh=cfg.mesh, shard_axes=shard_axes,
                      fold_shift=cfg.fold_shift)
        return kw

    start_fn = gpic_segment_start if local else distributed_gpic_segment_start
    step_fn = gpic_segment if local else distributed_gpic_segment
    fin_fn = gpic_segment_finalize if local else distributed_gpic_segment_finalize

    def attempt(use_pallas):
        carry = iso = None
        if cfg.ckpt_dir is not None:
            like = power_carry_like(n, cfg.n_vectors, len(si))
            tree, step, path, skipped = ckpt.restore_latest_valid(
                cfg.ckpt_dir, like)
            for p in skipped:
                notes.append(f"checkpoint_skipped:{os.path.basename(p)}")
            if tree is not None:
                carry = tree
                iso = jnp.asarray(
                    ckpt.manifest_extra(path).get("isolated_rows", 0),
                    jnp.int32)
                notes.append(f"resumed:{step}")
        kw = seg_kwargs(use_pallas)
        while True:
            t_now = 0
            if carry is not None:
                t_now = int(jax.device_get(carry.t))
                if (t_now >= cfg.max_iter
                        or bool(jax.device_get(jnp.all(carry.done)))):
                    break
            if segment_injector is not None:
                segment_injector(t_now)
            stop = jnp.int32(min(t_now + ce, cfg.max_iter))
            before = ops.kernel_fallbacks()
            t0 = time.monotonic()
            if carry is None:
                carry, iso = start_fn(x, stop, key=krand,
                                      n_vectors=cfg.n_vectors, **kw)
            else:
                carry = step_fn(x, carry, stop, **kw)
            jax.block_until_ready(carry.v)
            sec = time.monotonic() - t0
            t_after = int(jax.device_get(carry.t))
            monitor.record(t_after, sec)
            if (cfg.straggler_timeout is not None
                    and sec > cfg.straggler_timeout):
                notes.append(f"straggler:{t_after}:{sec:.3f}")
                raise StragglerTimeout(
                    f"segment ending at sweep {t_after} took {sec:.3f}s "
                    f"(straggler_timeout={cfg.straggler_timeout}s); "
                    "resuming from the last snapshot")
            new = tuple(sorted(o for o in ops.kernel_fallbacks()
                               if o not in before))
            if new and cfg.retry_on_fallback and use_pallas:
                raise _FallbackResume(new)   # tainted segment: NOT saved
            notes.extend(f"kernel_fallback:{o}" for o in new)
            if saver is not None:
                saver.save_async(
                    os.path.join(cfg.ckpt_dir, f"step_{t_after:06d}"),
                    carry, step=t_after,
                    extra={"isolated_rows": int(jax.device_get(iso)),
                           "sweep": t_after})
        before = ops.kernel_fallbacks()
        res = fin_fn(x, carry, iso, k, key=kkm, **fin_kwargs(use_pallas))
        jax.block_until_ready(res.labels)
        new = tuple(sorted(o for o in ops.kernel_fallbacks()
                           if o not in before))
        if new and cfg.retry_on_fallback and use_pallas:
            raise _FallbackResume(new)
        notes.extend(f"kernel_fallback:{o}" for o in new)
        return res

    use_pallas = cfg.use_pallas
    retries = 0
    try:
        while True:
            try:
                return attempt(use_pallas), tuple(notes)
            except _FallbackResume as e:
                if saver is not None:
                    saver.wait()     # land pending snapshots before restore
                notes.extend(f"kernel_fallback_resumed:{o}"
                             for o in e.fallback_ops)
                use_pallas = False   # not a retry: a consistency downgrade
            except GPICError as e:
                if saver is not None:
                    saver.wait()
                retries += 1
                if retries > cfg.max_retries:
                    raise
                notes.append(f"retry:{retries}:{type(e).__name__}")
                if cfg.backoff:
                    time.sleep(cfg.backoff * (2 ** (retries - 1)))
    finally:
        if saver is not None:
            saver.wait()
