"""The GPIC front door: one config dataclass, one entry point.

Every scenario the repo supports — local or sharded, explicit / streaming /
matrix-free, any affinity kind, any number of power vectors — is a field
combination on :class:`GPICConfig`; :func:`run_gpic` routes it to the right
operator-backed entry point. Examples, benchmarks, and launch/ call this
instead of hand-assembling keyword lists against five functions.

    from repro.core import GPICConfig, run_gpic

    # single device, paper-faithful
    res = run_gpic(x, k=4, config=GPICConfig(affinity_kind="rbf", sigma=0.3))

    # production config: sharded A-free streaming on a mesh
    cfg = GPICConfig(engine="streaming", mesh=mesh, shard_axes="data",
                     affinity_kind="rbf", sigma=0.3, n_vectors=4)
    res = run_gpic(shard_points(x, mesh), k=4, config=cfg)

Routing table (operator names from core/operators.py):

    mesh   engine        entry point                    operator
    ------ ------------- ------------------------------ ---------------------------
    None   explicit      gpic(engine='explicit')        explicit_operator
    None   streaming     gpic(engine='streaming')       streaming_operator
    None   matrix_free   gpic_matrix_free               matrix_free_operator
    set    explicit      distributed_gpic               sharded_explicit_operator
    set    streaming     distributed_gpic('streaming')  sharded_streaming_operator
    set    matrix_free   distributed_gpic_matrix_free   sharded_matrix_free_operator
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..kernels import ops
from .affinity import AffinityKind, AffinitySpec, as_affinity_spec
from .distributed import distributed_gpic, distributed_gpic_matrix_free
from .gpic import gpic, gpic_matrix_free
from .health import raise_for_health, validate_features
from .pic import PICResult
from .power import EMBEDDINGS

ENGINES = ("explicit", "streaming", "matrix_free")


@dataclass(frozen=True)
class GPICConfig:
    """Everything that selects and tunes a GPIC run, in one hashable value.

    Engine / placement:
      engine:       'explicit' (paper-faithful A build), 'streaming'
                    (A-free tile regeneration), or 'matrix_free' (factored
                    jnp product, cosine kinds only).
      mesh:         None → single device; a Mesh → sharded via shard_map
                    (pass row-sharded x, e.g. from ``shard_points``).
      shard_axes:   mesh axis name(s) the rows stripe over.

    Clustering:
      affinity:     an :class:`AffinitySpec` — the full graph-construction
                    policy (kind, bandwidth: fixed sigma or adaptive local
                    scaling, kNN truncation; DESIGN.md §11). None derives
                    the dense fixed spec from affinity_kind/sigma.
      affinity_kind/sigma: legacy shorthand for the dense fixed spec
                    (sigma only read for 'rbf'); rejected alongside a
                    non-None ``affinity`` so the two routes cannot
                    silently disagree.
      n_vectors:    r power vectors in one engine state (O3).
      embedding:    'pic' (classic per-column loop), 'orthogonal' (block
                    iteration: column 0 pinned to the classic trajectory,
                    columns 1..r-1 QR-orthonormalized into the invariant
                    subspace — the nested-structure fix, DESIGN.md §10),
                    or 'ensemble' (diffusion-time snapshot concatenation).
      qr_every:     re-orthonormalization period in sweeps ('orthogonal').
      residual_tol: arm the subspace residual stopping rule ('orthogonal'
                    with n_vectors > 1): once column 0 converges
                    classically, a relative ||WV − VΛ|| residual below
                    this on a QR step stops the whole block instead of
                    running to max_iter (DESIGN.md §11). None = off (the
                    bitwise PR-3 loop).
      snapshot_iters: ascending iteration counts to snapshot ('ensemble';
                    None = geometric in max_iter).
      eps_scale:    convergence threshold numerator (eps = eps_scale / n).
      max_iter / kmeans_iters: loop caps.

    Performance:
      a_dtype:      A-stripe storage dtype ('explicit' engines; bf16 = O4).
      fold_shift:   O5 — fold the cosine_shifted transform out of the
                    O(n²/P) build (sharded explicit engine only).
      tile:         Pallas tile edge override (None = static autotuner).
      block_sparse: route truncated (kNN) specs through the fused one-pass
                    build and the block-CSR sweeps, so sweep traffic
                    tracks nnz instead of n² (DESIGN.md §13). False keeps
                    the dense-storage two-pass path — bitwise-equal
                    results, the comparison baseline. No effect on dense
                    specs or the matrix-free engine.
      use_pallas:   False routes every op to the jnp reference oracles.
      seed:         key for k-means init + extra power vectors when
                    ``run_gpic`` isn't handed an explicit key.

    Robustness (DESIGN.md §12):
      sanitize:     zero-fill non-finite feature values at the front door
                    (recorded in ``PICResult.health.notes``) instead of
                    raising :class:`~repro.core.health.NonFiniteInputError`.
      component_probe: run the on-device disconnected-component check on
                    truncated (kNN) graphs; the count lands in
                    ``PICResult.health.n_components``. False skips the
                    probe's extra sweeps.
      retry_on_fallback: when a kernel falls back to its reference oracle
                    MID-RUN (``kernel_fallback:<op>`` would be noted), the
                    trajectory mixes kernel and reference ops. True
                    re-runs the whole pipeline on the reference oracles
                    (``use_pallas=False``) for a CONSISTENT trajectory;
                    the note upgrades to ``kernel_fallback_retried:<op>``.
    """
    engine: str = "explicit"
    mesh: Mesh | None = None
    shard_axes: str | Sequence[str] = "data"
    affinity: AffinitySpec | None = None
    affinity_kind: AffinityKind = "cosine_shifted"
    sigma: float = 1.0
    n_vectors: int = 1
    embedding: str = "pic"
    qr_every: int = 1
    residual_tol: float | None = None
    snapshot_iters: Sequence[int] | None = None
    eps_scale: float = 1e-5
    max_iter: int = 50
    kmeans_iters: int = 25
    a_dtype: Any = jnp.float32
    fold_shift: bool = False
    tile: int | None = None
    block_sparse: bool = True
    use_pallas: bool = True
    seed: int = 0
    sanitize: bool = False
    component_probe: bool = True
    retry_on_fallback: bool = False

    def with_(self, **updates) -> "GPICConfig":
        """Functional update (``dataclasses.replace`` with a shorter name)."""
        return replace(self, **updates)


def run_gpic(
    x: jax.Array,
    k: int,
    config: GPICConfig | None = None,
    *,
    key: jax.Array | None = None,
    **overrides,
) -> PICResult:
    """Run GPIC as described by ``config`` (plus keyword overrides).

    ``x`` is the (n, m) feature matrix — row-sharded on ``config.mesh``
    for distributed runs (see ``shard_points``), a plain array otherwise.
    Returns the extended :class:`PICResult` (full (n, r) embedding,
    per-column iteration stats, and the populated ``health`` report).

    Robustness contract (DESIGN.md §12): degenerate inputs raise a typed
    :class:`~repro.core.health.GPICError` subclass at the front door
    (non-finite features unless ``sanitize``, n < k, constant rows) or
    after the run (every row isolated, every power column dead); anything
    less total returns normally with the damage described in
    ``result.health`` — never silent garbage.
    """
    cfg = config or GPICConfig()
    if overrides:
        cfg = cfg.with_(**overrides)
    if cfg.engine not in ENGINES:
        raise ValueError(
            f"unknown engine {cfg.engine!r} (expected one of {ENGINES})")
    if cfg.embedding not in EMBEDDINGS:
        raise ValueError(
            f"unknown embedding {cfg.embedding!r} "
            f"(expected one of {EMBEDDINGS})")
    if cfg.qr_every < 1:
        raise ValueError(
            f"qr_every must be >= 1 (a period in sweeps), got {cfg.qr_every}")
    if cfg.qr_every != 1 and cfg.embedding != "orthogonal":
        raise ValueError(
            "qr_every tunes the re-orthonormalization period of "
            "embedding='orthogonal' only")
    if cfg.snapshot_iters is not None and cfg.embedding != "ensemble":
        raise ValueError(
            "snapshot_iters selects the diffusion times of "
            "embedding='ensemble' only")
    if cfg.residual_tol is not None:
        if cfg.embedding != "orthogonal":
            raise ValueError(
                "residual_tol arms the subspace residual stopping rule of "
                "embedding='orthogonal' only")
        if cfg.n_vectors < 2:
            raise ValueError(
                "residual_tol stops the QR-coupled block columns; with "
                "n_vectors=1 the orthogonal loop IS the classic one and "
                "the rule can never arm — drop it or raise n_vectors")
        if not float(cfg.residual_tol) > 0.0:
            raise ValueError(
                f"residual_tol must be > 0 (a relative residual), got "
                f"{cfg.residual_tol}")
    # resolve the affinity spec: an explicit AffinitySpec wins; setting it
    # ALONGSIDE non-default legacy shorthand is ambiguous and rejected
    # (sigma <= 0 and bad bandwidth/kind combos are rejected by the spec's
    # own constructor; neighbor-rank bounds need n and are checked here)
    if cfg.affinity is not None and (
            cfg.affinity_kind != "cosine_shifted" or cfg.sigma != 1.0):
        raise ValueError(
            "set either GPICConfig.affinity (the full spec) or the legacy "
            "affinity_kind/sigma shorthand, not both")
    spec = as_affinity_spec(cfg.affinity, kind=cfg.affinity_kind,
                            sigma=cfg.sigma)
    spec.validate_for_n(x.shape[0])
    # reject field combinations the selected route would silently ignore —
    # the front door must not mask misconfiguration a direct call rejects
    if cfg.engine == "matrix_free":
        dropped = [name for name, bad in (
            ("fold_shift", cfg.fold_shift),
            ("tile", cfg.tile is not None),
            ("a_dtype", cfg.a_dtype != jnp.float32),
        ) if bad]
        if dropped:
            raise ValueError(
                f"engine='matrix_free' does not use {dropped} (the factored "
                "jnp sweep has no A storage or Pallas tiles)")
        if not spec.factorable:
            raise ValueError(
                "engine='matrix_free' needs a factorable affinity spec "
                "(cosine kinds, fixed bandwidth, no truncation); got "
                f"{spec} — use the explicit or streaming engine for "
                "adaptive/kNN graphs")
    elif cfg.fold_shift and (cfg.mesh is None or cfg.engine != "explicit"
                             or spec.kind != "cosine_shifted"
                             or not spec.dense_fixed):
        raise ValueError(
            "fold_shift (O5) applies only to the sharded explicit engine "
            "with a dense fixed cosine_shifted spec (the shift being "
            "folded has no closed form on a truncated row)")
    if cfg.engine == "streaming" and cfg.a_dtype != jnp.float32:
        raise ValueError(
            "a_dtype (O4) selects the A *storage* dtype; the streaming "
            "engine never stores A")
    if key is None:
        key = jax.random.key(cfg.seed)

    # front-door input validation (typed errors; value checks skip under
    # a tracer and the device-side latches carry the load)
    x, health_notes = validate_features(x, k, sanitize=cfg.sanitize)
    fallbacks_before = ops.kernel_fallbacks()

    snapshot_iters = (None if cfg.snapshot_iters is None
                      else tuple(cfg.snapshot_iters))
    common = dict(key=key, max_iter=cfg.max_iter,
                  kmeans_iters=cfg.kmeans_iters,
                  affinity=spec, n_vectors=cfg.n_vectors,
                  embedding=cfg.embedding, qr_every=cfg.qr_every,
                  snapshot_iters=snapshot_iters,
                  residual_tol=cfg.residual_tol)

    def _route(c: GPICConfig) -> PICResult:
        if c.mesh is None:
            if c.engine == "matrix_free":
                return gpic_matrix_free(x, k, eps=c.eps_scale / x.shape[0],
                                        use_pallas=c.use_pallas, **common)
            return gpic(
                x, k, engine=c.engine, a_dtype=c.a_dtype,
                tile=c.tile, use_pallas=c.use_pallas,
                block_sparse=c.block_sparse,
                eps=c.eps_scale / x.shape[0],
                probe_components=c.component_probe, **common)
        shard_axes = (c.shard_axes if isinstance(c.shard_axes, str)
                      else tuple(c.shard_axes))
        if c.engine == "matrix_free":
            return distributed_gpic_matrix_free(
                x, k, mesh=c.mesh, shard_axes=shard_axes,
                eps_scale=c.eps_scale, use_pallas=c.use_pallas, **common)
        return distributed_gpic(
            x, k, mesh=c.mesh, shard_axes=shard_axes,
            engine=c.engine, eps_scale=c.eps_scale,
            a_dtype=c.a_dtype, fold_shift=c.fold_shift,
            tile=c.tile, use_pallas=c.use_pallas,
            block_sparse=c.block_sparse,
            probe_components=c.component_probe, **common)

    res = _route(cfg)

    # attach host-side events (sanitization, kernel fallbacks that first
    # fired during this run) and apply the unusable-result checks
    new_fallback_ops = tuple(sorted(
        op for op in ops.kernel_fallbacks() if op not in fallbacks_before))
    note_tag = "kernel_fallback"
    if new_fallback_ops and cfg.retry_on_fallback and cfg.use_pallas:
        # a mid-run fallback leaves a MIXED kernel/reference trajectory
        # (only the ops that failed were served by their oracles); re-run
        # the whole pipeline on the reference oracles so every sweep of
        # the reported result came from ONE consistent implementation
        res = _route(cfg.with_(use_pallas=False))
        note_tag = "kernel_fallback_retried"
    new_fallbacks = tuple(
        f"{note_tag}:{op}" for op in new_fallback_ops)
    notes = tuple(health_notes) + new_fallbacks
    if res.health is not None and notes:
        res = replace(res, health=replace(
            res.health, notes=res.health.notes + notes))
    if res.health is not None:
        raise_for_health(res.health, x.shape[0])
    return res
