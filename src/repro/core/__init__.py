"""Core GPIC library: the paper's contribution as composable JAX modules."""
from .affinity import (
    affinity_chunked,
    affinity_matrix,
    degree_matrix_free,
    matmat_matrix_free,
    matvec_matrix_free,
    rbf_bandwidth_heuristic,
    row_normalize_features,
)
from .gpic import gpic, gpic_matrix_free
from .operators import (
    explicit_operator,
    matrix_free_operator,
    mesh_reductions,
    sharded_explicit_operator,
    sharded_matrix_free_operator,
    sharded_streaming_operator,
    streaming_operator,
)
from .pipeline import ENGINES, GPICConfig, run_gpic
from .power import (
    PowerOperator,
    as_operator,
    batched_power_iteration,
    init_power_vectors,
    init_power_vectors_local,
    standardize_columns,
)
from .kmeans import kmeans, kmeans_objective, kmeans_plus_plus_init
from .metrics import adjusted_rand_index, jaccard_index, purity, rand_index
from .pic import (
    PICResult,
    make_pic_result,
    pic_from_affinity,
    pic_reference,
    pic_serial_numpy,
)

__all__ = [
    "affinity_matrix",
    "affinity_chunked",
    "as_operator",
    "batched_power_iteration",
    "init_power_vectors",
    "init_power_vectors_local",
    "matmat_matrix_free",
    "matvec_matrix_free",
    "degree_matrix_free",
    "standardize_columns",
    "row_normalize_features",
    "rbf_bandwidth_heuristic",
    "kmeans",
    "kmeans_objective",
    "kmeans_plus_plus_init",
    "adjusted_rand_index",
    "jaccard_index",
    "rand_index",
    "purity",
    "ENGINES",
    "GPICConfig",
    "run_gpic",
    "PowerOperator",
    "PICResult",
    "make_pic_result",
    "pic_reference",
    "pic_from_affinity",
    "pic_serial_numpy",
    "gpic",
    "gpic_matrix_free",
    "explicit_operator",
    "streaming_operator",
    "matrix_free_operator",
    "mesh_reductions",
    "sharded_explicit_operator",
    "sharded_matrix_free_operator",
    "sharded_streaming_operator",
]
