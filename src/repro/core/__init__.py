"""Core GPIC library: the paper's contribution as composable JAX modules."""
from .affinity import (
    affinity_chunked,
    affinity_matrix,
    degree_matrix_free,
    matmat_matrix_free,
    matvec_matrix_free,
    rbf_bandwidth_heuristic,
    row_normalize_features,
)
from .gpic import gpic, gpic_matrix_free
from .power import (
    batched_power_iteration,
    init_power_vectors,
    standardize_columns,
)
from .kmeans import kmeans, kmeans_objective, kmeans_plus_plus_init
from .metrics import adjusted_rand_index, jaccard_index, purity, rand_index
from .pic import PICResult, pic_from_affinity, pic_reference, pic_serial_numpy

__all__ = [
    "affinity_matrix",
    "affinity_chunked",
    "batched_power_iteration",
    "init_power_vectors",
    "matmat_matrix_free",
    "matvec_matrix_free",
    "degree_matrix_free",
    "standardize_columns",
    "row_normalize_features",
    "rbf_bandwidth_heuristic",
    "kmeans",
    "kmeans_objective",
    "kmeans_plus_plus_init",
    "adjusted_rand_index",
    "jaccard_index",
    "rand_index",
    "purity",
    "PICResult",
    "pic_reference",
    "pic_from_affinity",
    "pic_serial_numpy",
    "gpic",
    "gpic_matrix_free",
]
