"""Two-pass affinity-graph construction (pass 1) over the kernel registry.

This module turns an :class:`~repro.core.affinity.AffinitySpec` into the
per-row statistic arrays the pass-2 kernels consume (DESIGN.md §11):

  pass 1a  adaptive local scales   sigma_i = ||x_i - x_(scale_k)||
           from the streamed row-top-k of -d² (stat='neg_sqdist')
  pass 1b  truncation thresholds   tau_i = row's knn_k-th largest
           similarity (stat='similarity', adaptive scales applied)

Both passes stream through ``kernels.ops.row_topk`` — no (n, n) array is
ever allocated, so the A-free engines keep their O(n·m) residency. The
dense default spec skips pass 1 entirely (``affinity_stats`` returns
(None, None)) and pass 2 compiles the exact PR-3 kernels.

Sharded callers reuse :func:`scales_from_topk` on their stripe/ring
top-k reductions (core/operators.py); the dense jnp oracles live in
core/affinity.py (``local_scales`` / ``knn_thresholds``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.row_topk import topk_thresholds_from_scores
from .affinity import SCALE_FLOOR, AffinitySpec


def scales_from_topk(neg_sqdist_topk: jax.Array) -> jax.Array:
    """(R,) adaptive local scales from an (R, k) neg-sq-dist top-k buffer:
    sigma_i = sqrt(k-th smallest d²), floored at ``SCALE_FLOOR`` so
    duplicated points cannot zero the sigma_i * sigma_j denominator."""
    kth = jnp.maximum(-neg_sqdist_topk[:, -1], 0.0)
    return jnp.maximum(jnp.sqrt(kth), SCALE_FLOOR)


def affinity_stats(
    x: jax.Array,
    spec: AffinitySpec,
    *,
    tile: int | None = None,
    use_pallas: bool = True,
) -> tuple[jax.Array | None, jax.Array | None]:
    """(scale, thr) pass-1 statistics for the square self-affinity of ``x``.

    Either entry is None when the spec does not need it; the dense
    fixed-bandwidth default returns (None, None) without launching
    anything — keeping the default build bitwise-pinned to PR 3.
    """
    scale = thr = None
    if spec.adaptive:
        nk = ops.row_topk(
            x, k=spec.scale_k, stat="neg_sqdist", spec=spec,
            tm=tile, tn=tile, force_reference=not use_pallas)
        scale = scales_from_topk(nk)
    if spec.truncated:
        tk = ops.row_topk(
            x, k=spec.knn_k, stat="similarity", spec=spec,
            scale_r=scale, scale_c=scale,
            tm=tile, tn=tile, force_reference=not use_pallas)
        thr = tk[:, -1]
    return scale, thr


def fused_affinity_build(
    x: jax.Array,
    xc: jax.Array | None = None,
    *,
    spec: AffinitySpec,
    scale_r: jax.Array | None = None,
    scale_c: jax.Array | None = None,
    tm: int | None = None,
    tn: int | None = None,
    use_pallas: bool = True,
    a_dtype=jnp.float32,
    row_offset: jax.Array | int = 0,
    col_offset: jax.Array | int = 0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(A, D, thr) one-pass truncated build for the explicit engines
    (DESIGN.md §13) — replaces pass 1b + the masked rebuild with ONE sweep
    over the feature blocks plus cheap epilogues:

      1. build the stripe UNMASKED at f32 (the similarity pass the old
         row-top-k kernel re-did is the build itself)
      2. thr from ``topk_thresholds_from_scores`` — bitwise-equal to the
         streamed pass-1b statistic (shared tile transform + exact order
         statistic, both value-selecting)
      3. elementwise re-mask ``a >= thr[:, None]`` — bitwise-equal to the
         in-tile mask of the old rebuild (same f32 values, same compare),
         then cast to the storage dtype (same rounding the kernel applies)
      4. degrees by replaying the build kernel's fused RowSum on the
         masked f32 stripe: one ``jnp.sum(axis=1)`` per (·, tn) tile
         column (the kernel's per-tile VPU row sum on the same values)
         accumulated left-to-right in tile order (the kernel's sequential
         ``+=`` across the grid) — bitwise-equal to the old two-pass
         build's degrees (and to the streaming engines', the cross-engine
         discipline) WITHOUT re-scoring the features in a second kernel
         sweep

    The old two-pass path (``affinity_stats`` + masked build) remains the
    ``block_sparse=False`` route of the operators; this function is
    bitwise-equal to it, asserted in tests/test_block_sparse.py.

    Adaptive scales stay a caller concern (they come from the neg-sq-dist
    pass, which has no build to fuse into). Callers resolve (tm, tn) once
    and reuse them for the block plan and every sweep.
    """
    assert spec.truncated, "fused_affinity_build is the truncated-spec build"
    a_raw, _ = ops.affinity_and_degree(
        x, xc, spec=spec, scale_r=scale_r, scale_c=scale_c, thr=None,
        tm=tm, tn=tn, out_dtype=jnp.float32,
        row_offset=row_offset, col_offset=col_offset,
        force_reference=not use_pallas,
    )
    thr = topk_thresholds_from_scores(
        a_raw, k=spec.knn_k, row_offset=row_offset, col_offset=col_offset)
    a_f32 = jnp.where(a_raw >= thr[:, None], a_raw, 0.0)
    n_rows, n_cols = a_f32.shape
    _, tn_r = ops.resolve_tiles(
        n_cols, tm, tn, m=x.shape[1],
        a_bytes=jnp.dtype(jnp.float32).itemsize)
    cp = -(-n_cols // tn_r) * tn_r
    ap = jnp.pad(a_f32, ((0, 0), (0, cp - n_cols)))
    d = jnp.sum(ap[:, :tn_r], axis=1)
    for j in range(1, cp // tn_r):
        d = d + jnp.sum(ap[:, j * tn_r:(j + 1) * tn_r], axis=1)
    return a_f32.astype(a_dtype), d, thr
