"""Two-pass affinity-graph construction (pass 1) over the kernel registry.

This module turns an :class:`~repro.core.affinity.AffinitySpec` into the
per-row statistic arrays the pass-2 kernels consume (DESIGN.md §11):

  pass 1a  adaptive local scales   sigma_i = ||x_i - x_(scale_k)||
           from the streamed row-top-k of -d² (stat='neg_sqdist')
  pass 1b  truncation thresholds   tau_i = row's knn_k-th largest
           similarity (stat='similarity', adaptive scales applied)

Both passes stream through ``kernels.ops.row_topk`` — no (n, n) array is
ever allocated, so the A-free engines keep their O(n·m) residency. The
dense default spec skips pass 1 entirely (``affinity_stats`` returns
(None, None)) and pass 2 compiles the exact PR-3 kernels.

Sharded callers reuse :func:`scales_from_topk` on their stripe/ring
top-k reductions (core/operators.py); the dense jnp oracles live in
core/affinity.py (``local_scales`` / ``knn_thresholds``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .affinity import SCALE_FLOOR, AffinitySpec


def scales_from_topk(neg_sqdist_topk: jax.Array) -> jax.Array:
    """(R,) adaptive local scales from an (R, k) neg-sq-dist top-k buffer:
    sigma_i = sqrt(k-th smallest d²), floored at ``SCALE_FLOOR`` so
    duplicated points cannot zero the sigma_i * sigma_j denominator."""
    kth = jnp.maximum(-neg_sqdist_topk[:, -1], 0.0)
    return jnp.maximum(jnp.sqrt(kth), SCALE_FLOOR)


def affinity_stats(
    x: jax.Array,
    spec: AffinitySpec,
    *,
    tile: int | None = None,
    use_pallas: bool = True,
) -> tuple[jax.Array | None, jax.Array | None]:
    """(scale, thr) pass-1 statistics for the square self-affinity of ``x``.

    Either entry is None when the spec does not need it; the dense
    fixed-bandwidth default returns (None, None) without launching
    anything — keeping the default build bitwise-pinned to PR 3.
    """
    scale = thr = None
    if spec.adaptive:
        nk = ops.row_topk(
            x, k=spec.scale_k, stat="neg_sqdist", spec=spec,
            tm=tile, tn=tile, force_reference=not use_pallas)
        scale = scales_from_topk(nk)
    if spec.truncated:
        tk = ops.row_topk(
            x, k=spec.knn_k, stat="similarity", spec=spec,
            scale_r=scale, scale_c=scale,
            tm=tile, tn=tile, force_reference=not use_pallas)
        thr = tk[:, -1]
    return scale, thr
