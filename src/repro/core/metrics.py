"""External cluster-validation indices used by the paper's Experiment II:

Adjusted Rand Index (Hubert & Arabie 1985) and the Jaccard index (pair-counting
form), plus purity. Host-side numpy — metrics are evaluation-only.
"""
from __future__ import annotations

import numpy as np


def _contingency(labels_true: np.ndarray, labels_pred: np.ndarray) -> np.ndarray:
    labels_true = np.asarray(labels_true).ravel()
    labels_pred = np.asarray(labels_pred).ravel()
    if labels_true.shape != labels_pred.shape:
        raise ValueError("label arrays must have the same length")
    _, ti = np.unique(labels_true, return_inverse=True)
    _, pi = np.unique(labels_pred, return_inverse=True)
    c = np.zeros((ti.max() + 1, pi.max() + 1), np.int64)
    np.add.at(c, (ti, pi), 1)
    return c


def _comb2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.float64)
    return x * (x - 1.0) / 2.0


def adjusted_rand_index(labels_true, labels_pred) -> float:
    """ARI in [-1, 1]; 1 = identical partitions, ~0 = random agreement."""
    c = _contingency(labels_true, labels_pred)
    n = c.sum()
    sum_ij = _comb2(c).sum()
    a = _comb2(c.sum(axis=1)).sum()
    b = _comb2(c.sum(axis=0)).sum()
    expected = a * b / max(_comb2(np.array([n])).item(), 1.0)
    max_index = 0.5 * (a + b)
    denom = max_index - expected
    if denom == 0.0:
        return 1.0 if sum_ij == max_index else 0.0
    return float((sum_ij - expected) / denom)


def pair_confusion(labels_true, labels_pred) -> tuple[float, float, float, float]:
    """Pair counts (a, b, c, d): same/same, same/diff, diff/same, diff/diff."""
    cont = _contingency(labels_true, labels_pred)
    n = cont.sum()
    total_pairs = _comb2(np.array([n])).item()
    sum_ij = _comb2(cont).sum()                      # a: agree-positive pairs
    a_rows = _comb2(cont.sum(axis=1)).sum()          # same in true
    a_cols = _comb2(cont.sum(axis=0)).sum()          # same in pred
    b = a_rows - sum_ij                              # same-true, diff-pred
    c = a_cols - sum_ij                              # diff-true, same-pred
    d = total_pairs - sum_ij - b - c
    return float(sum_ij), float(b), float(c), float(d)


def jaccard_index(labels_true, labels_pred) -> float:
    """Pair-counting Jaccard: a / (a + b + c)."""
    a, b, c, _d = pair_confusion(labels_true, labels_pred)
    denom = a + b + c
    return float(a / denom) if denom > 0 else 1.0


def rand_index(labels_true, labels_pred) -> float:
    a, b, c, d = pair_confusion(labels_true, labels_pred)
    return float((a + d) / max(a + b + c + d, 1.0))


def purity(labels_true, labels_pred) -> float:
    c = _contingency(labels_true, labels_pred)
    return float(c.max(axis=0).sum() / max(c.sum(), 1))
