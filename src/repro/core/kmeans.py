"""k-means (kmeans++ init + Lloyd iterations) in pure JAX.

Used as the final step of PIC/GPIC (cluster the 1-D power-iteration embedding)
and, more generally, on (n, d) embeddings (e.g. LM token-embedding clustering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """(n, d) x (k, d) -> (n, k) squared euclidean distances."""
    xx = jnp.sum(x * x, axis=1)[:, None]
    cc = jnp.sum(c * c, axis=1)[None, :]
    return jnp.maximum(xx + cc - 2.0 * (x @ c.T), 0.0)


def kmeans_plus_plus_init(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """kmeans++ seeding: iteratively sample points proportional to D^2."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    cents0 = jnp.tile(x[first][None, :], (k, 1))

    def body(i, carry):
        cents, key, mind2 = carry
        d2_new = jnp.sum((x - cents[i - 1]) ** 2, axis=1)
        mind2 = jnp.minimum(mind2, d2_new)
        key, sub = jax.random.split(key)
        p = mind2 / jnp.maximum(jnp.sum(mind2), 1e-30)
        idx = jax.random.choice(sub, n, p=p)
        cents = cents.at[i].set(x[idx])
        return cents, key, mind2

    mind2 = jnp.full((n,), jnp.inf, x.dtype)
    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents0, key, mind2))
    return cents


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(
    key: jax.Array, x: jax.Array, k: int, iters: int = 25
) -> tuple[jax.Array, jax.Array]:
    """Lloyd's algorithm. Returns (labels (n,), centroids (k, d)).

    Empty clusters keep their previous centroid (standard fix; keeps the
    update well-defined under jit).
    """
    x = x.astype(jnp.float32)
    cents = kmeans_plus_plus_init(key, x, k)

    def step(cents, _):
        d2 = _pairwise_sqdist(x, cents)
        assign = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)      # (n, k)
        counts = jnp.sum(onehot, axis=0)                        # (k,)
        sums = onehot.T @ x                                     # (k, d)
        new = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], cents
        )
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    labels = jnp.argmin(_pairwise_sqdist(x, cents), axis=1).astype(jnp.int32)
    return labels, cents


def kmeans_objective(x: jax.Array, labels: jax.Array, cents: jax.Array) -> jax.Array:
    """Sum of squared distances to assigned centroids (inertia)."""
    return jnp.sum(jnp.sum((x - cents[labels]) ** 2, axis=1))
