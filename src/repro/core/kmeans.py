"""k-means (kmeans++ init + Lloyd iterations) on the (op, mode) kernel registry.

Used as the final step of PIC/GPIC (cluster the power-iteration embedding)
and, more generally, on (n, d) embeddings (e.g. LM token-embedding
clustering). The Lloyd assignment step — the O(n·k·d) hot loop — dispatches
through ``kernels.ops.kmeans_assign``: the fused Pallas kernel computes the
squared distances on the MXU and the argmin on the VPU with no (n, k)
distance matrix in HBM; ``force_reference=True`` routes it to the pure-jnp
oracle (same math, unfused HLO), mirroring every other op in the registry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops


def kmeans_plus_plus_init(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """kmeans++ seeding: iteratively sample points proportional to D^2."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    cents0 = jnp.tile(x[first][None, :], (k, 1))

    def body(i, carry):
        cents, key, mind2 = carry
        d2_new = jnp.sum((x - cents[i - 1]) ** 2, axis=1)
        mind2 = jnp.minimum(mind2, d2_new)
        key, sub = jax.random.split(key)
        p = mind2 / jnp.maximum(jnp.sum(mind2), 1e-30)
        idx = jax.random.choice(sub, n, p=p)
        cents = cents.at[i].set(x[idx])
        return cents, key, mind2

    mind2 = jnp.full((n,), jnp.inf, x.dtype)
    cents, _, _ = jax.lax.fori_loop(1, k, body, (cents0, key, mind2))
    return cents


def _canonicalize(labels: jax.Array, cents: jax.Array, k: int):
    """Relabel clusters in order of first appearance (point 0's cluster
    becomes id 0, the next unseen cluster id 1, ...). Cluster ids then
    depend only on the PARTITION, not on the kmeans++ sampling order — so
    two runs whose embeddings differ by reduction-order noise (e.g. the
    sharded vs single-device engines) produce bitwise-identical labels
    whenever they produce the same clustering. Centroids are permuted to
    match. Empty clusters sort last (stable)."""
    n = labels.shape[0]
    first = jnp.min(
        jnp.where(labels[None, :] == jnp.arange(k)[:, None],
                  jnp.arange(n)[None, :], n),
        axis=1)                                   # (k,) first index per id
    order = jnp.argsort(first)                    # old ids by first appearance
    rank = jnp.argsort(order)                     # old id -> canonical id
    return rank[labels].astype(jnp.int32), cents[order]


@functools.partial(jax.jit, static_argnames=("k", "iters", "force_reference"))
def kmeans(
    key: jax.Array, x: jax.Array, k: int, iters: int = 25,
    force_reference: bool = False, *, init: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Lloyd's algorithm. Returns (labels (n,), centroids (k, d)).

    An emptied cluster is reseeded to the point farthest from its assigned
    centroid (the i-th emptied cluster takes the i-th farthest point, so
    multiple empties land on distinct points) — deterministic given the
    seeded init, and it keeps all k clusters populated instead of letting
    two centroids collapse onto one blob (the old keep-previous-centroid
    fix could return fewer than k distinct labels under adversarial init).
    The assignment step runs the fused Pallas kernel unless
    ``force_reference`` routes it to the jnp oracle. Labels are
    canonicalized by first appearance (see ``_canonicalize``).
    ``init`` overrides the kmeans++ seeding with explicit (k, d) starting
    centroids (robustness tests drive the empty-cluster reseed with it).
    """
    x = x.astype(jnp.float32)
    n = x.shape[0]
    cents = (kmeans_plus_plus_init(key, x, k) if init is None
             else jnp.asarray(init, jnp.float32))

    def step(cents, _):
        assign, d2 = ops.kmeans_assign(x, cents,
                                       force_reference=force_reference)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)      # (n, k)
        counts = jnp.sum(onehot, axis=0)                        # (k,)
        sums = onehot.T @ x                                     # (k, d)
        empty = counts == 0
        # farthest-point reseed: i-th empty slot takes the i-th farthest
        # point (argsort is stable — deterministic under ties)
        order = jnp.argsort(-d2)                                # (n,) desc
        slot = jnp.clip(jnp.cumsum(empty) - 1, 0, n - 1)        # (k,)
        new = jnp.where(empty[:, None], x[order[slot]],
                        sums / jnp.maximum(counts, 1.0)[:, None])
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    labels, _ = ops.kmeans_assign(x, cents, force_reference=force_reference)
    return _canonicalize(labels, cents, k)


def kmeans_objective(x: jax.Array, labels: jax.Array, cents: jax.Array) -> jax.Array:
    """Sum of squared distances to assigned centroids (inertia)."""
    return jnp.sum(jnp.sum((x - cents[labels]) ** 2, axis=1))
