"""PowerOperator builders — every GPIC scenario as one engine binding.

The convergence engine (core/power.py) is parameterized by a
:class:`~repro.core.power.PowerOperator`; this module is the ONLY place
operators are assembled (DESIGN.md §9). Local builders bind the reduction
primitives to jnp identities; sharded builders (called INSIDE a
``shard_map`` body) bind them to ``psum``/``pmax``/``all_gather`` over the
mesh axes and realize the sweep with the exact same `(op, mode)` kernel
dispatch (kernels/ops.py) the single-device path uses — bf16 A storage,
autotuned tiles, streamed tile regeneration and all.

Every builder takes an :class:`~repro.core.affinity.AffinitySpec` (legacy
``kind``/``sigma`` kwargs coerce to the dense fixed spec). Specs that need
pass-1 statistics (adaptive local scaling, kNN truncation — DESIGN.md §11)
run the streamed row-top-k reduction first:

  local builders           one self-stripe row_topk per statistic
  sharded explicit         row_topk on the local (n/P, n) stripe against
                           the gathered features; local scales are
                           all-gathered once (an O(n) collective) so the
                           column side of exp(-d²/(σᵢσⱼ)) is available
  sharded streaming ring   an extra ppermute ring sweep per statistic:
                           per-stage (n/P, n/P) row_topk partials merged
                           with ``row_topk_merge`` as the feature blocks
                           rotate — pass 1 never materializes anything
                           larger than the (n/P, k) buffer

Operator menu (entry points in core/gpic.py, core/pic.py,
core/distributed.py, front door in core/pipeline.py):

  explicit_operator            square Pallas A build + fused mat-mat sweeps
  streaming_operator           A-free: tiles regenerated inside each sweep
  matrix_free_operator         factored jnp product (factorable specs only)
  sharded_explicit_operator    per-device (n/P, n) stripe of the SAME
                               Pallas build; V replicated per sweep
  sharded_matrix_free_operator X̂ row-sharded; O(m r) collectives per sweep
  sharded_streaming_operator   row-striped features, ring-rotated col
                               blocks (ppermute): O(n·m/P) peak memory per
                               device AND all affinity specs — the
                               production configuration
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.row_topk import row_topk_merge
from .affinity import (
    AffinityKind,
    AffinitySpec,
    as_affinity_spec,
    block_plan,
    dense_block_live,
    matmat_matrix_free,
    row_normalize_features,
)
from .graph import affinity_stats, fused_affinity_build, scales_from_topk
from .power import PowerOperator


def _axis_tuple(axes) -> tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def mesh_reductions(axes):
    """(sum, max, all_gather) bound to collectives over the mesh axes."""
    axes = _axis_tuple(axes)
    return (
        lambda x: jax.lax.psum(x, axes),
        lambda x: jax.lax.pmax(x, axes),
        lambda x: jax.lax.all_gather(x, axes, axis=0, tiled=True),
    )


def _gram_binding(use_pallas: bool):
    """The operator's local-chunk Gram: the Pallas tall-skinny kernel, or
    its jnp oracle when the caller routes everything to references. Local
    and sharded builders share this so the block algebra of the orthogonal
    embedding runs the identical kernel on both paths (DESIGN.md §10)."""
    return functools.partial(ops.gram, force_reference=not use_pallas)


# ---------------------------------------------------------------------------
# Local operators (single device / single chunk)
# ---------------------------------------------------------------------------


def _dense_transpose_matmat(a):
    """Local Aᵀ V binding for explicit (stored-A) operators: positivity-only
    transpose product for the symmetrized reachability probe — plain jnp,
    probe-frequency work (a handful of matvecs), never the power sweep."""
    def matmat_t(v):
        return a.astype(jnp.float32).T @ v.astype(jnp.float32)
    return matmat_t


def explicit_operator(inp, *, spec: AffinitySpec | None = None,
                      kind: AffinityKind = "cosine_shifted",
                      sigma: float = 1.0, a_dtype=jnp.float32,
                      tile: int | None = None,
                      use_pallas: bool = True,
                      block_sparse: bool = True) -> PowerOperator:
    """Paper-faithful: build A once (optionally bf16-stored, O4), then
    fused degree-normalized mat-mat sweeps. ``inp`` is row-normalized
    features for the cosine kinds, raw features for rbf.

    Truncated specs with ``block_sparse=True`` (the default) take the
    one-pass fused build (core/graph.py::fused_affinity_build) and route
    every sweep through the block-CSR plan so sweep traffic tracks nnz
    (DESIGN.md §13); ``block_sparse=False`` keeps the dense-storage
    two-pass path — bitwise-equal results, the comparison baseline. Dense
    specs always take the unchanged dense path. Truncated specs also bind
    ``matmat_t`` so the component probe walks A + Aᵀ reachability."""
    spec = as_affinity_spec(spec, kind=kind, sigma=sigma)
    n, m = inp.shape
    use_bs = block_sparse and spec.truncated
    if use_bs:
        # one pinned tile resolution serves the build, the plan, and every
        # sweep — the plan's block coordinates are grid-relative, and the
        # autotuner's choice is call-shape-sensitive (kernels/ops.py)
        tm, tn = ops.resolve_tiles(n, tile, tile, m=m,
                                   a_bytes=jnp.dtype(a_dtype).itemsize)
        # a single column block can skip nothing, and its traced grid
        # lowers through a dynamic loop while the dense kernel's one-step
        # static grid inlines — a fusion difference the bitwise discipline
        # (DESIGN.md §13) forbids; degenerate grids keep the dense path
        use_bs = -(-n // tn) > 1
    if use_bs:
        scale = None
        if spec.adaptive:
            scale = scales_from_topk(ops.row_topk(
                inp, k=spec.scale_k, stat="neg_sqdist", spec=spec,
                tm=tile, tn=tile, force_reference=not use_pallas))
        a, d, _thr = fused_affinity_build(
            inp, spec=spec, scale_r=scale, scale_c=scale, tm=tm, tn=tn,
            use_pallas=use_pallas, a_dtype=a_dtype)
        counts, col_idx, max_b = block_plan(dense_block_live(a, tm, tn))

        def matmat(v):
            return ops.block_sparse_matmat(
                a, v, d, counts, col_idx, max_b, tm=tm, tn=tn,
                force_reference=not use_pallas)

        return PowerOperator(matmat=matmat, degree=d,
                             gram=_gram_binding(use_pallas),
                             matmat_t=_dense_transpose_matmat(a))

    scale, thr = affinity_stats(inp, spec, tile=tile, use_pallas=use_pallas)
    a, d = ops.affinity_and_degree(
        inp, spec=spec, scale_r=scale, scale_c=scale, thr=thr,
        tm=tile, tn=tile, out_dtype=a_dtype, force_reference=not use_pallas,
    )

    def matmat(v):
        return ops.degree_normalized_matmat(
            a, v, d, tm=tile, tn=tile, force_reference=not use_pallas)

    return PowerOperator(matmat=matmat, degree=d,
                         gram=_gram_binding(use_pallas),
                         matmat_t=(_dense_transpose_matmat(a)
                                   if spec.truncated else None))


def streaming_operator(inp, *, spec: AffinitySpec | None = None,
                       kind: AffinityKind = "cosine_shifted",
                       sigma: float = 1.0, tile: int | None = None,
                       use_pallas: bool = True,
                       block_sparse: bool = True) -> PowerOperator:
    """A-free: affinity tiles are regenerated from the feature slabs inside
    every power step (DESIGN.md §5). All specs incl. adaptive/kNN rbf;
    peak memory O(n m + n r + n k), no (n, n) allocation ever — pass 1
    streams through the row-top-k kernel.

    Truncated specs with ``block_sparse=True`` pay one extra A-free
    liveness pass at build time (kernels/block_sparse.block_liveness) and
    then regenerate ONLY the live feature tiles in every sweep — same
    bitwise results as the dense-grid streaming sweep, nnz-scaled grid
    steps (DESIGN.md §13). Truncated specs bind ``matmat_t`` (the
    column-thresholded streaming stripe — still A-free) so the component
    probe walks A + Aᵀ reachability."""
    spec = as_affinity_spec(spec, kind=kind, sigma=sigma)
    n, m = inp.shape
    scale, thr = affinity_stats(inp, spec, tile=tile, use_pallas=use_pallas)

    matmat_t = None
    if spec.truncated:
        def matmat_t(v):
            return ops.streaming_matmat(
                inp, v, None, spec=spec, scale_r=scale, scale_c=scale,
                thr=None, thr_c=thr, tm=tile, tn=tile,
                force_reference=not use_pallas)

    use_bs = block_sparse and spec.truncated
    if use_bs:
        tm, tn = ops.resolve_tiles(n, tile, tile, m=m)
        # degenerate single-column-block grids keep the dense-grid kernel
        # (see explicit_operator — same bitwise-discipline rationale)
        use_bs = -(-n // tn) > 1
    if use_bs:
        live = ops.block_liveness(
            inp, spec=spec, scale_r=scale, scale_c=scale, thr=thr,
            tm=tm, tn=tn, force_reference=not use_pallas)
        counts, col_idx, max_b = block_plan(live)
        d = ops.block_sparse_streaming_degree(
            inp, counts=counts, col_idx=col_idx, max_b=max_b,
            spec=spec, scale_r=scale, scale_c=scale, thr=thr,
            tm=tm, tn=tn, force_reference=not use_pallas)

        def matmat(v):
            return ops.block_sparse_streaming_matmat(
                inp, v, d, counts=counts, col_idx=col_idx, max_b=max_b,
                spec=spec, scale_r=scale, scale_c=scale, thr=thr,
                tm=tm, tn=tn, force_reference=not use_pallas)

        return PowerOperator(matmat=matmat, degree=d,
                             gram=_gram_binding(use_pallas),
                             matmat_t=matmat_t)

    d = ops.streaming_degree(
        inp, spec=spec, scale_r=scale, scale_c=scale, thr=thr,
        tm=tile, tn=tile, force_reference=not use_pallas,
    )

    def matmat(v):
        return ops.streaming_matmat(
            inp, v, d, spec=spec, scale_r=scale, scale_c=scale, thr=thr,
            tm=tile, tn=tile, force_reference=not use_pallas,
        )

    return PowerOperator(matmat=matmat, degree=d,
                         gram=_gram_binding(use_pallas),
                         matmat_t=matmat_t)


def matrix_free_operator(xn, *, spec: AffinitySpec | None = None,
                         kind: AffinityKind = "cosine_shifted",
                         use_pallas: bool = True) -> PowerOperator:
    """Factored jnp product A V = f(X̂(X̂ᵀV)) − V (O2): O(n·m·r) per sweep,
    factorable specs only (cosine kinds, no scaling/truncation — the
    rejection lives in ``matmat_matrix_free``). ``xn`` must be
    row-normalized. The sweep has no Pallas realization; ``use_pallas``
    governs the Gram binding only."""
    spec = as_affinity_spec(spec, kind=kind)
    n = xn.shape[0]
    d = matmat_matrix_free(xn, jnp.ones((n,), xn.dtype), spec)

    def matmat(v):
        return matmat_matrix_free(xn, v, spec) / jnp.maximum(d, 1e-30)[:, None]

    return PowerOperator(matmat=matmat, degree=d,
                         gram=_gram_binding(use_pallas))


# ---------------------------------------------------------------------------
# Sharded operators (call INSIDE a shard_map body; x_loc is the device's
# row block of the global (n, m) feature matrix)
# ---------------------------------------------------------------------------


def sharded_explicit_operator(x_loc, *, axes,
                              spec: AffinitySpec | None = None,
                              kind: AffinityKind = "cosine_shifted",
                              sigma: float = 1.0, a_dtype=jnp.float32,
                              fold_shift: bool = False,
                              tile: int | None = None,
                              use_pallas: bool = True,
                              block_sparse: bool = True) -> PowerOperator:
    """Per-device (n/P, n) stripe of the Pallas affinity build; V is
    replicated per sweep via all-gather (O(n r) bytes/step against
    O(n²/P) local compute — collective-light).

    Non-dense specs run pass 1 on the stripe: the local block's row-top-k
    against the gathered features (same tile program as the single-device
    pass, so the statistics match it bitwise), with the adaptive scales
    all-gathered once for the column side of the build.

    ``fold_shift`` (O5, cosine_shifted only) stores the stripe as RAW
    masked cosine (the (1+a)/2 transform never touches the O(n²/P) array)
    and folds the shift into an O(n_loc r) epilogue:
    (A V)_i = (ΣV − v_i + (A_cos V)_i)/2, d_i = (n − 1 + d_cos,i)/2.
    Folding is a storage-algebra trick on the DENSE matrix — a truncated
    row has no closed-form shift mass — so it requires a dense fixed spec.

    Truncated specs with ``block_sparse=True`` take the fused one-pass
    stripe build (thresholds from the stripe's own unmasked scores — the
    full row is present, so the epilogue statistic equals the streamed
    pass-1b bitwise) and block-CSR sweeps over the stripe's live tiles;
    they also bind ``matmat_t`` (psum of the local stripe's transpose
    partials) for the symmetrized component probe (DESIGN.md §13).
    """
    spec = as_affinity_spec(spec, kind=kind, sigma=sigma)
    if fold_shift and not spec.dense_fixed:
        raise ValueError(
            "fold_shift (O5) rewrites the dense shift algebra; it cannot "
            f"be combined with adaptive/truncated specs (got {spec})")
    psum, pmax, gather = mesh_reductions(axes)
    idx = jax.lax.axis_index(_axis_tuple(axes))
    n_loc = x_loc.shape[0]
    row0 = idx * n_loc
    if spec.kind != "rbf":
        x_loc = row_normalize_features(x_loc)
    x_full = gather(x_loc)
    n = x_full.shape[0]

    scale_loc = scale_full = thr_loc = None
    if spec.adaptive:
        nk = ops.row_topk(
            x_loc, x_full, k=spec.scale_k, stat="neg_sqdist", spec=spec,
            tm=tile, tn=tile, row_offset=row0,
            force_reference=not use_pallas)
        scale_loc = scales_from_topk(nk)
        scale_full = gather(scale_loc)

    def _stripe_matmat_t(a_loc):
        """Aᵀ V local chunk from the stored (n_loc, n) stripe: each device
        contributes its stripe's transpose partial, psum completes the
        column sums, and the local rows are sliced back out. Positivity-
        only probe work — the O(n r) collective runs a handful of times."""
        def matmat_t(v_loc):
            part = a_loc.astype(jnp.float32).T @ v_loc.astype(jnp.float32)
            return jax.lax.dynamic_slice_in_dim(psum(part), row0, n_loc)
        return matmat_t

    use_bs = block_sparse and spec.truncated
    if use_bs:
        tm, tn = ops.resolve_tiles(n, tile, tile, m=x_loc.shape[1],
                                   a_bytes=jnp.dtype(a_dtype).itemsize)
        # degenerate single-column-block grids keep the dense-grid kernel
        # (see explicit_operator — same bitwise-discipline rationale)
        use_bs = -(-n // tn) > 1
    if use_bs:
        a_loc, d_loc, thr_loc = fused_affinity_build(
            x_loc, x_full, spec=spec, scale_r=scale_loc, scale_c=scale_full,
            tm=tm, tn=tn, use_pallas=use_pallas, a_dtype=a_dtype,
            row_offset=row0)
        counts, col_idx, max_b = block_plan(dense_block_live(a_loc, tm, tn))

        def matmat(v_loc):
            v_full = gather(v_loc)
            return ops.block_sparse_matmat(
                a_loc, v_full, d_loc, counts, col_idx, max_b, tm=tm, tn=tn,
                force_reference=not use_pallas)

        return PowerOperator(matmat=matmat, degree=d_loc,
                             sum=psum, max=pmax, all_gather=gather,
                             gram=_gram_binding(use_pallas),
                             matmat_t=_stripe_matmat_t(a_loc))

    if spec.truncated:
        tk = ops.row_topk(
            x_loc, x_full, k=spec.knn_k, stat="similarity", spec=spec,
            scale_r=scale_loc, scale_c=scale_full,
            tm=tile, tn=tile, row_offset=row0,
            force_reference=not use_pallas)
        thr_loc = tk[:, -1]

    fold = fold_shift and spec.kind == "cosine_shifted"
    build_kind = "cosine" if fold else spec.kind
    a_loc, d_raw = ops.affinity_and_degree(
        x_loc, x_full, kind=build_kind, sigma=spec.sigma,
        scale_r=scale_loc, scale_c=scale_full, thr=thr_loc,
        tm=tile, tn=tile, out_dtype=a_dtype, row_offset=row0,
        force_reference=not use_pallas,
    )

    if fold:
        d_loc = 0.5 * (n - 1.0 + d_raw)
        ones = jnp.ones((n_loc,), jnp.float32)

        def matmat(v_loc):
            v_full = gather(v_loc)
            raw = ops.degree_normalized_matmat(     # (A_cos V) stripe, d=1
                a_loc, v_full, ones, tm=tile, tn=tile,
                force_reference=not use_pallas)
            sv = jnp.sum(v_full, axis=0)            # (r,) — V is replicated
            av = 0.5 * (sv[None, :] + raw - v_loc)
            return av / jnp.maximum(d_loc, 1e-30)[:, None]

    else:
        d_loc = d_raw

        def matmat(v_loc):
            v_full = gather(v_loc)
            return ops.degree_normalized_matmat(
                a_loc, v_full, d_loc, tm=tile, tn=tile,
                force_reference=not use_pallas)

    return PowerOperator(matmat=matmat, degree=d_loc,
                         sum=psum, max=pmax, all_gather=gather,
                         gram=_gram_binding(use_pallas),
                         matmat_t=(_stripe_matmat_t(a_loc)
                                   if spec.truncated else None))


def sharded_matrix_free_operator(x_loc, *, axes,
                                 spec: AffinitySpec | None = None,
                                 kind: AffinityKind = "cosine_shifted",
                                 use_pallas: bool = True) -> PowerOperator:
    """X̂ row-sharded factored product: per sweep one psum of an (m, r)
    block and one (r,) psum — O(m r) collectives, the configuration that
    scales to thousands of nodes. Factorable specs only (they factor)."""
    spec = as_affinity_spec(spec, kind=kind)
    psum, pmax, gather = mesh_reductions(axes)
    n_loc = x_loc.shape[0]
    xn_loc = row_normalize_features(x_loc)
    d_loc = matmat_matrix_free(
        xn_loc, jnp.ones((n_loc,), xn_loc.dtype), spec, psum=psum)

    def matmat(v_loc):
        av = matmat_matrix_free(xn_loc, v_loc, spec, psum=psum)
        return av / jnp.maximum(d_loc, 1e-30)[:, None]

    return PowerOperator(matmat=matmat, degree=d_loc,
                         sum=psum, max=pmax, all_gather=gather,
                         gram=_gram_binding(use_pallas))


def sharded_streaming_operator(x_loc, *, axes, mesh_size: int,
                               spec: AffinitySpec | None = None,
                               kind: AffinityKind = "cosine_shifted",
                               sigma: float = 1.0, tile: int | None = None,
                               use_pallas: bool = True,
                               block_sparse: bool = True,
                               inject_fault: tuple | None = None
                               ) -> PowerOperator:
    """Row-striped A-free engine: each sweep ring-rotates the (n/P, m)
    feature blocks (and the matching V blocks) around the mesh with
    ``ppermute``; every stage regenerates the (n/P, n/P) affinity stripe
    tiles on the fly and accumulates the partial product. Features are
    never gathered: peak per-device memory is O(n·m/P + n·r/P) — and the
    tile transform is elementwise, so EVERY affinity spec works (rbf,
    adaptive scaling and kNN truncation included). This is the production
    configuration: the only one that is simultaneously A-free, fully
    sharded, and all-specs (DESIGN.md §9, §11).

    Pass 1 for non-dense specs runs as extra ppermute ring sweeps BEFORE
    the degree sweep: per stage the row-top-k kernel scores the local rows
    against the block that just arrived and ``row_topk_merge`` folds the
    (n/P, k) partial into the running buffer — order-independent, so the
    statistics equal the single-device pass bitwise. The adaptive scales
    are then all-gathered once (an (n,) vector — negligible against the
    O(n·m/P) block budget) so every later stage can slice its column
    block's scales without a second ring.

    ``mesh_size`` is the static number of devices P spanned by ``axes``
    (ring length). Collectives per sweep: 2(P−1) ppermutes (the feature
    ring and the V ring rotate independently at each of the P−1 rotated
    stages), moving O(n(m+r)/P) bytes each — O(n(m+r)) total per device,
    the all-gather equivalent, but with O(n m / P) residency instead of
    O(n m).

    Truncated specs with ``block_sparse=True`` pay ONE extra liveness ring
    at build time: each stage emits its (nI, nJ) live-block map (A-free,
    kernels/block_sparse.block_liveness) into a stacked (P, nI, nJ) plan
    ring, and every later degree/mat-mat stage runs the block-sparse
    streaming kernel over stage ``s``'s slice of the stacked plan. The
    traced ``max_b`` grid bound is the MAX over all stages, so the stage
    launch is loop-invariant and one compiled kernel serves the whole
    ring (DESIGN.md §13). Bitwise-equal to the dense-grid ring. Truncated
    specs also bind ``matmat_t`` for the symmetrized component probe: a
    third ring rotating (features, V, thr) together, each stage computing
    the column-thresholded stripe (``thr_c`` — the arriving block's OWN
    row thresholds applied on the column side; exact because tile scores
    are bitwise symmetric) so the partials sum to the local rows of Aᵀ V
    without ever materializing A.

    ``inject_fault`` (static; fault-injection harness only, DESIGN.md §12)
    corrupts one mat-mat ring stage: ``("ring_nan", s)`` poisons the V
    block consumed at stage ``s`` of every sweep with NaN — a simulated
    transient interconnect corruption the power loop's non-finite latches
    must detect and contain.
    """
    if inject_fault is not None and (
            len(inject_fault) != 2 or inject_fault[0] != "ring_nan"
            or not 0 <= int(inject_fault[1]) < mesh_size):
        raise ValueError(
            f"inject_fault must be ('ring_nan', stage<{mesh_size}), got "
            f"{inject_fault!r}")
    spec = as_affinity_spec(spec, kind=kind, sigma=sigma)
    psum, pmax, gather = mesh_reductions(axes)
    axes_t = _axis_tuple(axes)
    idx = jax.lax.axis_index(axes_t)
    n_loc = x_loc.shape[0]
    row0 = idx * n_loc
    if spec.kind != "rbf":
        x_loc = row_normalize_features(x_loc)
    perm = [(i, (i - 1) % mesh_size) for i in range(mesh_size)]

    def ring(x):
        return jax.lax.ppermute(x, axes_t, perm)

    def _col0(s):
        return ((idx + s) % mesh_size) * n_loc

    # the last stage's block is consumed in place — rotating it again would
    # be a pure-waste collective, so all sweeps (top-k pass 1, degrees,
    # mat-mat) run P-1 rotated stages in the fori_loop and apply stage P-1
    # outside it

    def topk_ring_sweep(k, stat, scale_full):
        """(n_loc, k) merged top-k of the local rows vs every ring block."""
        def partial(s, x_ring):
            scl_c = (None if scale_full is None else
                     jax.lax.dynamic_slice_in_dim(
                         scale_full, _col0(s), n_loc))
            return ops.row_topk(
                x_loc, x_ring, k=k, stat=stat, spec=spec,
                scale_r=None if scale_full is None else scale_loc,
                scale_c=scl_c, tm=tile, tn=tile,
                row_offset=row0, col_offset=_col0(s),
                force_reference=not use_pallas)

        def stage(s, carry):
            buf, x_ring = carry
            buf = row_topk_merge(buf, partial(s, x_ring), k)
            return buf, ring(x_ring)
        buf0 = jnp.full((n_loc, k), -jnp.inf, jnp.float32)
        buf, x_ring = jax.lax.fori_loop(0, mesh_size - 1, stage,
                                        (buf0, x_loc))
        return row_topk_merge(buf, partial(mesh_size - 1, x_ring), k)

    scale_loc = scale_full = thr_loc = None
    if spec.adaptive:
        scale_loc = scales_from_topk(
            topk_ring_sweep(spec.scale_k, "neg_sqdist", None))
        scale_full = gather(scale_loc)
    if spec.truncated:
        thr_loc = topk_ring_sweep(
            spec.knn_k, "similarity", scale_full)[:, -1]

    def _stage_scales(s):
        if scale_full is None:
            return None, None
        return scale_loc, jax.lax.dynamic_slice_in_dim(
            scale_full, _col0(s), n_loc)

    matmat_t = None
    if spec.truncated:
        def matmat_t(v_loc):
            # ring Aᵀ V: rotate (features, V, thr) together; the arriving
            # block's own row thresholds mask the stripe on the COLUMN side
            # (thr_c), so each stage's tile (i, j) equals A[c0+j, r0+i] —
            # tile scores are bitwise symmetric — and the stage partials
            # sum to the local rows of Aᵀ V. Unnormalized (probe-only).
            def partial(s, x_ring, v_ring, thr_ring):
                scl_r, scl_c = _stage_scales(s)
                return ops.streaming_matmat(
                    x_loc, v_ring, None, x_ring, spec=spec,
                    scale_r=scl_r, scale_c=scl_c, thr=None, thr_c=thr_ring,
                    tm=tile, tn=tile, row_offset=row0, col_offset=_col0(s),
                    force_reference=not use_pallas)

            def stage(s, carry):
                u, x_ring, v_ring, thr_ring = carry
                u = u + partial(s, x_ring, v_ring, thr_ring)
                return u, ring(x_ring), ring(v_ring), ring(thr_ring)
            u0 = jnp.zeros((n_loc, v_loc.shape[1]), jnp.float32)
            u, x_ring, v_ring, thr_ring = jax.lax.fori_loop(
                0, mesh_size - 1, stage,
                (u0, x_loc, v_loc.astype(jnp.float32), thr_loc))
            return u + partial(mesh_size - 1, x_ring, v_ring, thr_ring)

    use_bs = block_sparse and spec.truncated
    if use_bs:
        tm, tn = ops.resolve_tiles(n_loc, tile, tile, m=x_loc.shape[1])
        # degenerate single-column-block stage grids keep the dense-grid
        # ring (see explicit_operator — same bitwise-discipline rationale)
        use_bs = -(-n_loc // tn) > 1
    if use_bs:

        def liveness_ring():
            def partial(s, x_ring):
                scl_r, scl_c = _stage_scales(s)
                return ops.block_liveness(
                    x_loc, x_ring, spec=spec, scale_r=scl_r, scale_c=scl_c,
                    thr=thr_loc, tm=tm, tn=tn,
                    row_offset=row0, col_offset=_col0(s),
                    force_reference=not use_pallas)

            def stage(s, carry):
                acc, x_ring = carry
                acc = jax.lax.dynamic_update_index_in_dim(
                    acc, partial(s, x_ring), s, axis=0)
                return acc, ring(x_ring)
            n_i = -(-n_loc // tm)
            n_j = -(-n_loc // tn)
            acc, x_ring = jax.lax.fori_loop(
                0, mesh_size - 1, stage,
                (jnp.zeros((mesh_size, n_i, n_j), jnp.int32), x_loc))
            return jax.lax.dynamic_update_index_in_dim(
                acc, partial(mesh_size - 1, x_ring), mesh_size - 1, axis=0)

        # stacked (P, nI, nJ) plan ring; max_b is the global max so the
        # per-stage kernel launch is loop-invariant (one compiled program)
        counts_all, col_idx_all, max_bs = jax.vmap(block_plan)(
            liveness_ring())
        max_b = jnp.max(max_bs)

        def degree_sweep_bs():
            def partial(s, x_ring):
                scl_r, scl_c = _stage_scales(s)
                return ops.block_sparse_streaming_degree(
                    x_loc, x_ring, counts=counts_all[s],
                    col_idx=col_idx_all[s], max_b=max_b,
                    spec=spec, scale_r=scl_r, scale_c=scl_c,
                    thr=thr_loc, tm=tm, tn=tn,
                    row_offset=row0, col_offset=_col0(s),
                    force_reference=not use_pallas)

            def stage(s, carry):
                d, x_ring = carry
                return d + partial(s, x_ring), ring(x_ring)
            d, x_ring = jax.lax.fori_loop(
                0, mesh_size - 1, stage,
                (jnp.zeros((n_loc,), jnp.float32), x_loc))
            return d + partial(mesh_size - 1, x_ring)

        d_loc = degree_sweep_bs()

        def matmat(v_loc):
            def partial(s, x_ring, v_ring):
                if inject_fault is not None:
                    v_ring = jnp.where(s == int(inject_fault[1]),
                                       jnp.float32(jnp.nan), v_ring)
                scl_r, scl_c = _stage_scales(s)
                return ops.block_sparse_streaming_matmat(
                    x_loc, v_ring, None, x_ring, counts=counts_all[s],
                    col_idx=col_idx_all[s], max_b=max_b,
                    spec=spec, scale_r=scl_r, scale_c=scl_c, thr=thr_loc,
                    tm=tm, tn=tn, row_offset=row0, col_offset=_col0(s),
                    force_reference=not use_pallas)

            def stage(s, carry):
                u, x_ring, v_ring = carry
                u = u + partial(s, x_ring, v_ring)
                return u, ring(x_ring), ring(v_ring)
            u0 = jnp.zeros((n_loc, v_loc.shape[1]), jnp.float32)
            u, x_ring, v_ring = jax.lax.fori_loop(
                0, mesh_size - 1, stage,
                (u0, x_loc, v_loc.astype(jnp.float32)))
            u = u + partial(mesh_size - 1, x_ring, v_ring)
            return u / jnp.maximum(d_loc, 1e-30)[:, None]

        return PowerOperator(matmat=matmat, degree=d_loc,
                             sum=psum, max=pmax, all_gather=gather,
                             gram=_gram_binding(use_pallas),
                             matmat_t=matmat_t)

    def degree_sweep():
        def partial(s, x_ring):
            scl_r, scl_c = _stage_scales(s)
            return ops.streaming_degree(
                x_loc, x_ring, spec=spec, scale_r=scl_r, scale_c=scl_c,
                thr=thr_loc, tm=tile, tn=tile,
                row_offset=row0, col_offset=_col0(s),
                force_reference=not use_pallas)

        def stage(s, carry):
            d, x_ring = carry
            return d + partial(s, x_ring), ring(x_ring)
        d, x_ring = jax.lax.fori_loop(
            0, mesh_size - 1, stage,
            (jnp.zeros((n_loc,), jnp.float32), x_loc))
        return d + partial(mesh_size - 1, x_ring)

    d_loc = degree_sweep()

    def matmat(v_loc):
        def partial(s, x_ring, v_ring):
            if inject_fault is not None:
                # poison only the block CONSUMED at the faulted stage (the
                # rotating carry stays clean — a transient corruption, not
                # a persistently dead link)
                v_ring = jnp.where(s == int(inject_fault[1]),
                                   jnp.float32(jnp.nan), v_ring)
            scl_r, scl_c = _stage_scales(s)
            return ops.streaming_matmat(
                x_loc, v_ring, None, x_ring, spec=spec,
                scale_r=scl_r, scale_c=scl_c, thr=thr_loc,
                tm=tile, tn=tile, row_offset=row0, col_offset=_col0(s),
                force_reference=not use_pallas)

        def stage(s, carry):
            u, x_ring, v_ring = carry
            u = u + partial(s, x_ring, v_ring)
            return u, ring(x_ring), ring(v_ring)
        u0 = jnp.zeros((n_loc, v_loc.shape[1]), jnp.float32)
        u, x_ring, v_ring = jax.lax.fori_loop(
            0, mesh_size - 1, stage, (u0, x_loc, v_loc.astype(jnp.float32)))
        u = u + partial(mesh_size - 1, x_ring, v_ring)
        return u / jnp.maximum(d_loc, 1e-30)[:, None]

    return PowerOperator(matmat=matmat, degree=d_loc,
                         sum=psum, max=pmax, all_gather=gather,
                         gram=_gram_binding(use_pallas),
                         matmat_t=matmat_t)
