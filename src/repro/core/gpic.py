"""GPIC — the accelerated Power Iteration Clustering pipeline (Algorithm 2).

This is the paper's contribution as a composable JAX module. The six CUDA
kernels map onto two fused Pallas kernels plus O(n) epilogues (DESIGN.md §2):

    paper kernel 1 AffinityMatrix ┐
    paper kernel 2 RowSum         ┴→ kernels.ops.affinity_and_degree  (fused)
    paper kernel 3 NormMatrix      → eliminated: W v = D^-1 (A v)      (O1b)
    paper kernel 6 Multiply       ┐
    paper kernel 4 Reduction      ┴→ kernels.ops.power_step            (fused)
    paper kernel 5 Norm            → O(n) epilogue inside power_step

``gpic`` (explicit A) is the paper-faithful accelerated path; it converges to
the same result as ``pic_reference`` (the paper's exactness claim).
``gpic_matrix_free`` is the beyond-paper O2 path: O(n·m) per iteration, no A.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels import ops
from .affinity import AffinityKind, matvec_matrix_free, row_normalize_features
from .kmeans import kmeans
from .pic import PICResult, standardize_embedding


def _truncated_power_iteration(matvec_over_degree, v0, eps, max_iter):
    """Shared stopping logic (paper Algorithm 2 lines 6-15)."""

    def cond(state):
        t, _v, _delta, done = state
        return jnp.logical_and(t < max_iter, jnp.logical_not(done))

    def body(state):
        t, v, delta, _done = state
        u = matvec_over_degree(v)                       # (A v)/d fused kernel
        v_next = u / jnp.maximum(jnp.sum(jnp.abs(u)), 1e-30)
        delta_next = jnp.abs(v_next - v)
        accel = jnp.max(jnp.abs(delta_next - delta))
        return t + 1, v_next, delta_next, accel <= eps

    state = (jnp.int32(0), v0, v0, jnp.bool_(False))
    t, v, _d, done = jax.lax.while_loop(cond, body, state)
    return v, t, done


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "max_iter", "kmeans_iters", "affinity_kind", "sigma",
        "n_vectors", "use_pallas", "tile",
    ),
)
def gpic(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    eps: float | None = None,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    sigma: float = 1.0,
    n_vectors: int = 1,
    use_pallas: bool = True,
    tile: int = 256,
) -> PICResult:
    """Accelerated PIC with explicit A (the paper-faithful GPIC pipeline)."""
    n = x.shape[0]
    if eps is None:
        eps = 1e-5 / n

    inp = x if affinity_kind == "rbf" else row_normalize_features(x)
    a, d = ops.affinity_and_degree(
        inp, kind=affinity_kind, sigma=sigma, tm=tile, tn=tile,
        force_reference=not use_pallas,
    )
    v0 = d / jnp.maximum(jnp.sum(d), 1e-30)

    def mv(v):
        return ops.degree_normalized_matvec(
            a, v, d, tm=tile, tn=tile, force_reference=not use_pallas
        )

    kkm, krand = jax.random.split(key)
    v, n_iter, converged = _truncated_power_iteration(mv, v0, eps, max_iter)
    if n_vectors > 1:
        u0 = jax.random.uniform(krand, (n_vectors - 1, n), v0.dtype)
        u0 = u0 / jnp.sum(u0, axis=1, keepdims=True)
        extra, _, _ = jax.vmap(
            lambda vv: _truncated_power_iteration(mv, vv, eps, max_iter)
        )(u0)
        emb = jnp.concatenate(
            [standardize_embedding(v)[:, None],
             jax.vmap(standardize_embedding)(extra).T], axis=1)
    else:
        emb = standardize_embedding(v)[:, None]
    labels, _ = kmeans(kkm, emb, k, iters=kmeans_iters)
    return PICResult(labels=labels, embedding=v, n_iter=n_iter, converged=converged)


@functools.partial(
    jax.jit,
    static_argnames=("k", "max_iter", "kmeans_iters", "affinity_kind", "n_vectors"),
)
def gpic_matrix_free(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    eps: float | None = None,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    n_vectors: int = 1,
) -> PICResult:
    """Beyond-paper O2: PIC without materializing A (cosine kinds only).

    Per-iteration cost O(n·m) and memory O(n·m) — the paper's 36.5 GB
    (n = 45k) A matrix is never built. Exact same math as the explicit path.
    """
    n = x.shape[0]
    if eps is None:
        eps = 1e-5 / n
    xn = row_normalize_features(x)
    d = matvec_matrix_free(xn, jnp.ones((n,), xn.dtype), affinity_kind)
    v0 = d / jnp.maximum(jnp.sum(d), 1e-30)

    def mv(v):
        return matvec_matrix_free(xn, v, affinity_kind) / jnp.maximum(d, 1e-30)

    kkm, krand = jax.random.split(key)
    v, n_iter, converged = _truncated_power_iteration(mv, v0, eps, max_iter)
    if n_vectors > 1:
        u0 = jax.random.uniform(krand, (n_vectors - 1, n), v0.dtype)
        u0 = u0 / jnp.sum(u0, axis=1, keepdims=True)
        extra, _, _ = jax.vmap(
            lambda vv: _truncated_power_iteration(mv, vv, eps, max_iter)
        )(u0)
        emb = jnp.concatenate(
            [standardize_embedding(v)[:, None],
             jax.vmap(standardize_embedding)(extra).T], axis=1)
    else:
        emb = standardize_embedding(v)[:, None]
    labels, _ = kmeans(kkm, emb, k, iters=kmeans_iters)
    return PICResult(labels=labels, embedding=v, n_iter=n_iter, converged=converged)
