"""GPIC — the accelerated Power Iteration Clustering pipeline (Algorithm 2).

This is the paper's contribution as a composable JAX module. The six CUDA
kernels map onto two fused Pallas kernels plus O(n) epilogues (DESIGN.md §2):

    paper kernel 1 AffinityMatrix ┐
    paper kernel 2 RowSum         ┴→ kernels.ops.affinity_and_degree  (fused)
    paper kernel 3 NormMatrix      → eliminated: W V = D^-1 (A V)      (O1b)
    paper kernel 6 Multiply       ┐
    paper kernel 4 Reduction      ┴→ kernels.ops.degree_normalized_matmat
    paper kernel 5 Norm            → O(n r) epilogue in the power loop

All paths assemble a PowerOperator (core/operators.py) and run the ONE
multi-vector convergence engine (core/power.py): the iteration state is one
(n, r) matrix and every iteration costs ONE sweep of A regardless of
``n_vectors`` (DESIGN.md §4, §9). Engines:

  engine='explicit'   paper-faithful: build A once (optionally bf16-stored,
                      f32-accumulated — O4), then fused mat-mat sweeps.
  engine='streaming'  A-free: affinity tiles are regenerated from the
                      feature slabs inside every power step (DESIGN.md §5).
                      Works for ALL affinity kinds including rbf; peak
                      memory O(n m + n r), no (n, n) allocation ever.

``gpic`` (explicit A) converges to the same result as ``pic_reference``
(the paper's exactness claim). ``gpic_matrix_free`` is the beyond-paper O2
jnp path: O(n·m) per iteration, cosine kinds only.

Every entry point takes ``embedding='pic' | 'orthogonal' | 'ensemble'``
(DESIGN.md §10): the classic per-column loop, the pinned-QR block
iteration (nested-structure quality fix; Gram products on the Pallas
tall-skinny kernel), or the diffusion-time snapshot ensemble.

Prefer the ``run_gpic``/``GPICConfig`` front door (core/pipeline.py) over
assembling these keyword lists by hand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .affinity import (
    AffinityKind,
    AffinitySpec,
    as_affinity_spec,
    row_normalize_features,
)
from .health import HealthReport, count_bad_rows, graph_component_probe
from .kmeans import kmeans
from .operators import (
    explicit_operator,
    matrix_free_operator,
    streaming_operator,
)
from .pic import PICResult, make_pic_result
from .power import (
    backfill_snapshots,
    batched_power_iteration,
    ensemble_embedding,
    finalize_power_carry,
    init_power_carry,
    init_power_vectors,
    power_iteration_segment,
    run_power_embedding,
    standardize_columns,
)

#: kept under its historical name for callers that batch a custom matvec
_truncated_power_iteration = batched_power_iteration


def _build_engine_operator(x, spec, *, engine, a_dtype=jnp.float32,
                           tile=None, use_pallas=True, block_sparse=True):
    """The ONE local operator construction: normalize features per the
    spec's kind and bind the selected engine. Shared by the monolithic
    entry points and the segmented (resumable) ones, so both trace the
    identical build — a prerequisite of the bitwise-resume guarantee
    (DESIGN.md §14)."""
    if engine == "matrix_free":
        return matrix_free_operator(row_normalize_features(x), spec=spec,
                                    use_pallas=use_pallas)
    inp = x if spec.kind == "rbf" else row_normalize_features(x)
    if engine == "explicit":
        return explicit_operator(inp, spec=spec, a_dtype=a_dtype, tile=tile,
                                 use_pallas=use_pallas,
                                 block_sparse=block_sparse)
    if engine == "streaming":
        return streaming_operator(inp, spec=spec, tile=tile,
                                  use_pallas=use_pallas,
                                  block_sparse=block_sparse)
    raise ValueError(f"unknown engine {engine!r} "
                     "(expected 'explicit' or 'streaming')")


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "max_iter", "kmeans_iters", "affinity_kind", "sigma",
        "affinity", "n_vectors", "use_pallas", "tile", "engine", "a_dtype",
        "embedding", "qr_every", "snapshot_iters", "residual_tol",
        "probe_components", "block_sparse",
    ),
)
def gpic(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    eps: float | None = None,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    sigma: float = 1.0,
    affinity: AffinitySpec | None = None,
    n_vectors: int = 1,
    use_pallas: bool = True,
    tile: int | None = None,
    engine: str = "explicit",
    a_dtype=jnp.float32,
    embedding: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple | None = None,
    residual_tol: float | None = None,
    probe_components: bool = True,
    block_sparse: bool = True,
) -> PICResult:
    """Accelerated PIC via the multi-vector power engine.

    ``affinity`` (an :class:`AffinitySpec`) selects the full
    graph-construction policy — adaptive local scaling, kNN truncation
    (DESIGN.md §11) — and takes precedence over the legacy
    ``affinity_kind``/``sigma`` shorthand. ``residual_tol`` arms the
    subspace residual stopping rule (embedding='orthogonal', DESIGN.md
    §11). ``tile=None`` lets the static autotuner pick the Pallas tile
    size; ``use_pallas=False`` routes every op to the pure-jnp reference
    implementations (same math, unfused HLO). ``block_sparse`` routes
    truncated (kNN) specs through the fused one-pass build and the
    block-CSR sweeps (DESIGN.md §13); False keeps the dense-storage
    two-pass path — bitwise-equal results either way.
    """
    n = x.shape[0]
    if eps is None:
        eps = 1e-5 / n
    spec = as_affinity_spec(affinity, kind=affinity_kind, sigma=sigma)
    spec.validate_for_n(n)

    if engine not in ("explicit", "streaming"):
        raise ValueError(f"unknown engine {engine!r} "
                         "(expected 'explicit' or 'streaming')")
    op = _build_engine_operator(x, spec, engine=engine, a_dtype=a_dtype,
                                tile=tile, use_pallas=use_pallas,
                                block_sparse=block_sparse)

    kkm, krand = jax.random.split(key)
    v0 = init_power_vectors(krand, op.degree, n_vectors)
    v, t_cols, done, emb_raw, status = run_power_embedding(
        op, v0, eps, max_iter, embedding=embedding, qr_every=qr_every,
        snapshot_iters=snapshot_iters, residual_tol=residual_tol)
    emb = standardize_columns(emb_raw)
    labels, _ = kmeans(kkm, emb, k, iters=kmeans_iters,
                       force_reference=not use_pallas)
    health = _local_health(op, status, n, spec,
                           probe_components=probe_components)
    return make_pic_result(labels, v, t_cols, done, embedding=embedding,
                           embeddings=emb_raw, health=health)


def _local_health(op, status, n, spec, *, probe_components=True):
    """Assemble the HealthReport of a local (single-chunk) run: isolated
    rows from the operator's degrees, the disconnected-component probe
    when the spec truncates (the only build that zeroes above-threshold
    structure; dense graphs disconnect only by underflow, which the
    isolated-row count already surfaces)."""
    if probe_components and spec is not None and spec.truncated:
        n_comp, comp = graph_component_probe(op, n)
    else:
        n_comp = jnp.int32(-1)
        comp = jnp.full((n,), -1, jnp.int32)
    return HealthReport(col_status=status,
                        isolated_rows=count_bad_rows(op.degree),
                        n_components=n_comp, components=comp)


@functools.partial(
    jax.jit,
    static_argnames=("k", "max_iter", "kmeans_iters", "affinity_kind",
                     "affinity", "n_vectors", "use_pallas", "embedding",
                     "qr_every", "snapshot_iters", "residual_tol"),
)
def gpic_matrix_free(
    x: jax.Array,
    k: int,
    *,
    key: jax.Array,
    eps: float | None = None,
    max_iter: int = 50,
    kmeans_iters: int = 25,
    affinity_kind: AffinityKind = "cosine_shifted",
    affinity: AffinitySpec | None = None,
    n_vectors: int = 1,
    use_pallas: bool = True,
    embedding: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple | None = None,
    residual_tol: float | None = None,
) -> PICResult:
    """Beyond-paper O2: PIC without materializing A (factorable specs only
    — cosine kinds, no adaptive scaling or truncation).

    Per-iteration cost O(n·m·r) and memory O(n·m) — the paper's 36.5 GB
    (n = 45k) A matrix is never built. Exact same math as the explicit path,
    run on the same batched engine state.
    """
    n = x.shape[0]
    if eps is None:
        eps = 1e-5 / n
    spec = as_affinity_spec(affinity, kind=affinity_kind)
    op = _build_engine_operator(x, spec, engine="matrix_free",
                                use_pallas=use_pallas)

    kkm, krand = jax.random.split(key)
    v0 = init_power_vectors(krand, op.degree, n_vectors)
    v, t_cols, done, emb_raw, status = run_power_embedding(
        op, v0, eps, max_iter, embedding=embedding, qr_every=qr_every,
        snapshot_iters=snapshot_iters, residual_tol=residual_tol)
    emb = standardize_columns(emb_raw)
    # the sweep itself is jnp either way; the flag still governs k-means
    labels, _ = kmeans(kkm, emb, k, iters=kmeans_iters,
                       force_reference=not use_pallas)
    # factorable specs are never truncated — the probe cannot arm
    health = _local_health(op, status, n, spec, probe_components=False)
    return make_pic_result(labels, v, t_cols, done, embedding=embedding,
                           embeddings=emb_raw, health=health)


# ---------------------------------------------------------------------------
# Segmented (resumable) execution — the local engines in bounded pieces
# ---------------------------------------------------------------------------
#
# The supervisor (core/pipeline.py) drives these three entry points when
# ``GPICConfig.checkpoint_every`` is set: ``gpic_segment_start`` builds the
# operator and seeds the sweep-0 carry exactly as the monolithic ``gpic``
# does, ``gpic_segment`` advances the carry by one bounded piece (the carry
# round-trips through train/checkpoint.py between calls), and
# ``gpic_segment_finalize`` closes the finished carry into the same
# PICResult the monolithic run returns — k-means, health, ensemble
# backfill. ``embedding`` is resolved to loop parameters by
# ``pipeline._segment_plan`` ('ensemble' runs mode='pic' with its snapshot
# schedule; the flatten happens at finalize). The loop body is the
# monolithic one (core/power.py), so results are bitwise (DESIGN.md §14).


@functools.partial(
    jax.jit,
    static_argnames=(
        "affinity", "engine", "a_dtype", "tile", "use_pallas",
        "block_sparse", "n_vectors", "mode", "qr_every", "snapshot_iters",
        "residual_tol",
    ),
)
def gpic_segment_start(
    x: jax.Array,
    stop: jax.Array,
    *,
    key: jax.Array,
    eps: float,
    affinity: AffinitySpec,
    engine: str = "explicit",
    a_dtype=jnp.float32,
    tile: int | None = None,
    use_pallas: bool = True,
    block_sparse: bool = True,
    n_vectors: int = 1,
    mode: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple = (),
    residual_tol: float | None = None,
):
    """Build the operator, seed the sweep-0 carry (the monolithic seeding,
    bitwise: ``key`` is the krand half of the front door's split), and run
    the first segment to ``stop``. Returns ``(carry, isolated_rows)`` —
    the isolated-row count rides in the checkpoint manifest so resumed
    attempts skip the degree recount."""
    op = _build_engine_operator(x, affinity, engine=engine, a_dtype=a_dtype,
                                tile=tile, use_pallas=use_pallas,
                                block_sparse=block_sparse)
    v0 = init_power_vectors(key, op.degree, n_vectors)
    carry = init_power_carry(v0, len(snapshot_iters))
    carry = power_iteration_segment(
        op, carry, eps, stop, mode=mode, qr_every=qr_every,
        snapshot_iters=snapshot_iters, residual_tol=residual_tol)
    return carry, count_bad_rows(op.degree)


@functools.partial(
    jax.jit,
    static_argnames=(
        "affinity", "engine", "a_dtype", "tile", "use_pallas",
        "block_sparse", "mode", "qr_every", "snapshot_iters", "residual_tol",
    ),
)
def gpic_segment(
    x: jax.Array,
    carry,
    stop: jax.Array,
    *,
    eps: float,
    affinity: AffinitySpec,
    engine: str = "explicit",
    a_dtype=jnp.float32,
    tile: int | None = None,
    use_pallas: bool = True,
    block_sparse: bool = True,
    mode: str = "pic",
    qr_every: int = 1,
    snapshot_iters: tuple = (),
    residual_tol: float | None = None,
):
    """Advance a restored carry by one bounded segment (rebuilds the
    operator from the features — the build is deterministic, so the
    regenerated sweeps are the ones the uninterrupted run performed)."""
    op = _build_engine_operator(x, affinity, engine=engine, a_dtype=a_dtype,
                                tile=tile, use_pallas=use_pallas,
                                block_sparse=block_sparse)
    return power_iteration_segment(
        op, carry, eps, stop, mode=mode, qr_every=qr_every,
        snapshot_iters=snapshot_iters, residual_tol=residual_tol)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "kmeans_iters", "affinity", "engine", "a_dtype", "tile",
        "use_pallas", "block_sparse", "embedding", "snapshot_iters",
        "probe_components",
    ),
)
def gpic_segment_finalize(
    x: jax.Array,
    carry,
    iso: jax.Array,
    k: int,
    *,
    key: jax.Array,
    kmeans_iters: int = 25,
    affinity: AffinitySpec,
    engine: str = "explicit",
    a_dtype=jnp.float32,
    tile: int | None = None,
    use_pallas: bool = True,
    block_sparse: bool = True,
    embedding: str = "pic",
    snapshot_iters: tuple = (),
    probe_components: bool = True,
) -> PICResult:
    """Close a finished carry into the monolithic run's PICResult:
    COL_MAXITER latching, the ensemble backfill/flatten, standardize,
    k-means (``key`` is the kkm half of the front door's split), and the
    health assembly. The operator is rebuilt only when the component
    probe arms (truncated specs)."""
    n = x.shape[0]
    t, v, t_cols, done, snaps, status = finalize_power_carry(carry)
    if embedding == "ensemble":
        snaps = backfill_snapshots(snaps, v, t, snapshot_iters)
        emb_raw = ensemble_embedding(snaps)
    else:
        emb_raw = v
    emb = standardize_columns(emb_raw)
    labels, _ = kmeans(key, emb, k, iters=kmeans_iters,
                       force_reference=not use_pallas)
    if probe_components and affinity.truncated:
        op = _build_engine_operator(
            x, affinity, engine=engine, a_dtype=a_dtype, tile=tile,
            use_pallas=use_pallas, block_sparse=block_sparse)
        n_comp, comp = graph_component_probe(op, n)
    else:
        n_comp = jnp.int32(-1)
        comp = jnp.full((n,), -1, jnp.int32)
    health = HealthReport(col_status=status,
                          isolated_rows=iso.astype(jnp.int32),
                          n_components=n_comp, components=comp)
    return make_pic_result(labels, v, t_cols, done, embedding=embedding,
                           embeddings=emb_raw, health=health)
