"""Batched truncated power iteration — the multi-vector engine core.

One ``(n, r)`` state matrix replaces r independent while-loops: every
iteration performs ONE degree-normalized mat-mat (one sweep of A, however
it is realized — explicit Pallas tiles, streamed tiles, or the factored
matrix-free product), so the per-iteration HBM traffic is independent of
the number of power vectors (DESIGN.md §4).

The engine is parameterized by a :class:`PowerOperator` (DESIGN.md §9):
``matmat`` performs the one sweep on the caller's *local* row chunk of the
state, and the ``sum``/``max``/``all_gather`` reduction primitives finish
the cross-chunk combines. Bound to plain jnp identities the engine IS the
single-device loop; bound to ``psum``/``pmax``/``all_gather`` over mesh
axes inside ``shard_map`` the SAME loop is the sharded one — there is no
second implementation of the convergence math anywhere in the repo.

Column semantics are EXACTLY the paper's per-vector Algorithm 1/2 loop
(lines 6-15): each column carries its own delta and acceleration-based
stopping flag, and a converged column is frozen (its value and delta stop
updating) while the remaining columns keep iterating. A column's trajectory
is therefore identical to what a dedicated single-vector loop would have
produced — the batching changes the cost model, not the math.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp


def _identity(x):
    return x


@dataclass(frozen=True)
class PowerOperator:
    """One degree-normalized sweep of A plus its reduction binding.

    Attributes:
      matmat: maps the local (n_loc, r) chunk of V to the local chunk of
        (A V) / d — ONE sweep of A however realized. Any gathering the
        realization needs (e.g. replicating V across a mesh before a
        stripe mat-mat) happens inside.
      degree: the local (n_loc,) degree chunk backing the sweep (v0 seed
        and diagnostics; None for bare-callable wrapping).
      sum: finishes a cross-chunk sum of an already-locally-reduced value
        (identity locally; ``psum`` over mesh axes when sharded).
      max: same for max (identity / ``pmax``).
      all_gather: maps a local (n_loc, ...) chunk to the global (n, ...)
        array (identity locally; tiled ``all_gather`` when sharded).
    """
    matmat: Callable[[jax.Array], jax.Array]
    degree: jax.Array | None = None
    sum: Callable[[jax.Array], jax.Array] = field(default=_identity)
    max: Callable[[jax.Array], jax.Array] = field(default=_identity)
    all_gather: Callable[[jax.Array], jax.Array] = field(default=_identity)


def as_operator(op) -> PowerOperator:
    """Wrap a bare ``matmat`` callable as a local (single-chunk) operator."""
    if isinstance(op, PowerOperator):
        return op
    return PowerOperator(matmat=op)


def batched_power_iteration(op, v0, eps, max_iter):
    """Run the truncated power iteration on batched state.

    Args:
      op: a :class:`PowerOperator`, or a bare callable mapping V (n, r) to
        (A V) / d (wrapped as a local operator).
      v0: (n_loc, r) initial vectors — the caller's local row chunk of the
        global (n, r) state (the whole state on a single device).
      eps: the paper's acceleration threshold (typically 1e-5 / n).
      max_iter: iteration cap.

    Returns:
      (V, t_cols, done): final local (n_loc, r) state, per-column iteration
      counts (r,) int32, and per-column convergence flags (r,) bool. The
      counts/flags are replicated across chunks; gather V with
      ``op.all_gather`` if the full embedding is needed.
    """
    op = as_operator(op)
    r = v0.shape[1]

    def cond(state):
        t, _v, _delta, done, _t_cols = state
        return jnp.logical_and(t < max_iter, jnp.logical_not(jnp.all(done)))

    def body(state):
        t, v, delta, done, t_cols = state
        u = op.matmat(v)                                        # (n_loc, r)
        l1 = op.sum(jnp.sum(jnp.abs(u), axis=0))                # (r,)
        v_next = u / jnp.maximum(l1, 1e-30)[None, :]
        delta_next = jnp.abs(v_next - v)
        accel = op.max(jnp.max(jnp.abs(delta_next - delta), axis=0))  # (r,)
        # columns already done are frozen: keep prior value/delta, don't
        # count the iteration; columns converging NOW keep this update
        # (the per-vector loop applies the converging step before stopping)
        v_next = jnp.where(done[None, :], v, v_next)
        delta_next = jnp.where(done[None, :], delta, delta_next)
        t_cols = t_cols + jnp.where(done, 0, 1).astype(jnp.int32)
        done = jnp.logical_or(done, accel <= eps)
        return t + 1, v_next, delta_next, done, t_cols

    state = (
        jnp.int32(0), v0, v0,                      # delta_0 <- v_0 (line 1)
        jnp.zeros((r,), bool), jnp.zeros((r,), jnp.int32),
    )
    _t, v, _delta, done, t_cols = jax.lax.while_loop(cond, body, state)
    return v, t_cols, done


def random_start_vectors(krand, n, n_vectors, dtype=jnp.float32):
    """(n, r-1) L1-normalized uniform random starts — columns 1..r-1 of the
    engine state (Lin & Cohen's multi-vector extension, O3). The single
    source of this recipe: single-host and distributed paths must draw
    bit-identical columns for their trajectories to agree."""
    if n_vectors <= 1:
        return jnp.zeros((n, 0), dtype)
    u0 = jax.random.uniform(krand, (n_vectors - 1, n), dtype)
    u0 = u0 / jnp.sum(u0, axis=1, keepdims=True)
    return u0.T


def init_power_vectors(krand, d, n_vectors, dtype=None):
    """Build the (n, r) start state: column 0 is the paper's degree start
    v_0 = D / sum(D) (Algorithm 2 lines 4-5); the rest are random starts."""
    dtype = dtype or d.dtype
    v0 = (d / jnp.maximum(jnp.sum(d), 1e-30)).astype(dtype)
    return jnp.concatenate(
        [v0[:, None], random_start_vectors(krand, d.shape[0], n_vectors, dtype)],
        axis=1)


def init_power_vectors_local(d_loc, u0t_loc, sum_fn=_identity, dtype=None):
    """Local-chunk variant of :func:`init_power_vectors`: column 0 is the
    degree start normalized by the GLOBAL degree mass (``sum_fn`` finishes
    the cross-chunk sum — identity locally, ``psum`` when sharded) and the
    remaining columns are the caller's local slice of the replicated random
    starts, so every chunk seeds exactly the single-device state."""
    dtype = dtype or d_loc.dtype
    dsum = sum_fn(jnp.sum(d_loc))
    v0 = (d_loc / jnp.maximum(dsum, 1e-30)).astype(dtype)
    return jnp.concatenate([v0[:, None], u0t_loc.astype(dtype)], axis=1)


def standardize_columns(v):
    """Per-column zero-mean / unit-variance rescale of the (n, r) embedding."""
    mu = jnp.mean(v, axis=0, keepdims=True)
    sd = jnp.maximum(jnp.std(v, axis=0, keepdims=True), 1e-30)
    return (v - mu) / sd
