"""Batched truncated power iteration — the multi-vector engine core.

One ``(n, r)`` state matrix replaces r independent while-loops: every
iteration performs ONE degree-normalized mat-mat (one sweep of A, however
it is realized — explicit Pallas tiles, streamed tiles, or the factored
matrix-free product), so the per-iteration HBM traffic is independent of
the number of power vectors (DESIGN.md §4).

Column semantics are EXACTLY the paper's per-vector Algorithm 1/2 loop
(lines 6-15): each column carries its own delta and acceleration-based
stopping flag, and a converged column is frozen (its value and delta stop
updating) while the remaining columns keep iterating. A column's trajectory
is therefore identical to what a dedicated single-vector loop would have
produced — the batching changes the cost model, not the math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_power_iteration(matmat_over_degree, v0, eps, max_iter):
    """Run the truncated power iteration on batched state.

    Args:
      matmat_over_degree: maps V (n, r) -> (A V) / d, one sweep of A.
      v0: (n, r) initial vectors (columns).
      eps: the paper's acceleration threshold (typically 1e-5 / n).
      max_iter: iteration cap.

    Returns:
      (V, t_cols, done): final (n, r) state, per-column iteration counts
      (r,) int32, and per-column convergence flags (r,) bool.
    """
    r = v0.shape[1]

    def cond(state):
        t, _v, _delta, done, _t_cols = state
        return jnp.logical_and(t < max_iter, jnp.logical_not(jnp.all(done)))

    def body(state):
        t, v, delta, done, t_cols = state
        u = matmat_over_degree(v)                               # (n, r)
        l1 = jnp.sum(jnp.abs(u), axis=0)                        # (r,)
        v_next = u / jnp.maximum(l1, 1e-30)[None, :]
        delta_next = jnp.abs(v_next - v)
        accel = jnp.max(jnp.abs(delta_next - delta), axis=0)    # (r,)
        # columns already done are frozen: keep prior value/delta, don't
        # count the iteration; columns converging NOW keep this update
        # (the per-vector loop applies the converging step before stopping)
        v_next = jnp.where(done[None, :], v, v_next)
        delta_next = jnp.where(done[None, :], delta, delta_next)
        t_cols = t_cols + jnp.where(done, 0, 1).astype(jnp.int32)
        done = jnp.logical_or(done, accel <= eps)
        return t + 1, v_next, delta_next, done, t_cols

    state = (
        jnp.int32(0), v0, v0,                      # delta_0 <- v_0 (line 1)
        jnp.zeros((r,), bool), jnp.zeros((r,), jnp.int32),
    )
    _t, v, _delta, done, t_cols = jax.lax.while_loop(cond, body, state)
    return v, t_cols, done


def random_start_vectors(krand, n, n_vectors, dtype=jnp.float32):
    """(n, r-1) L1-normalized uniform random starts — columns 1..r-1 of the
    engine state (Lin & Cohen's multi-vector extension, O3). The single
    source of this recipe: single-host and distributed paths must draw
    bit-identical columns for their trajectories to agree."""
    if n_vectors <= 1:
        return jnp.zeros((n, 0), dtype)
    u0 = jax.random.uniform(krand, (n_vectors - 1, n), dtype)
    u0 = u0 / jnp.sum(u0, axis=1, keepdims=True)
    return u0.T


def init_power_vectors(krand, d, n_vectors, dtype=None):
    """Build the (n, r) start state: column 0 is the paper's degree start
    v_0 = D / sum(D) (Algorithm 2 lines 4-5); the rest are random starts."""
    dtype = dtype or d.dtype
    v0 = (d / jnp.maximum(jnp.sum(d), 1e-30)).astype(dtype)
    return jnp.concatenate(
        [v0[:, None], random_start_vectors(krand, d.shape[0], n_vectors, dtype)],
        axis=1)


def standardize_columns(v):
    """Per-column zero-mean / unit-variance rescale of the (n, r) embedding."""
    mu = jnp.mean(v, axis=0, keepdims=True)
    sd = jnp.maximum(jnp.std(v, axis=0, keepdims=True), 1e-30)
    return (v - mu) / sd
