"""Batched truncated power iteration — the multi-vector engine core.

One ``(n, r)`` state matrix replaces r independent while-loops: every
iteration performs ONE degree-normalized mat-mat (one sweep of A, however
it is realized — explicit Pallas tiles, streamed tiles, or the factored
matrix-free product), so the per-iteration HBM traffic is independent of
the number of power vectors (DESIGN.md §4).

The engine is parameterized by a :class:`PowerOperator` (DESIGN.md §9):
``matmat`` performs the one sweep on the caller's *local* row chunk of the
state, and the ``sum``/``max``/``all_gather`` reduction primitives finish
the cross-chunk combines. Bound to plain jnp identities the engine IS the
single-device loop; bound to ``psum``/``pmax``/``all_gather`` over mesh
axes inside ``shard_map`` the SAME loop is the sharded one — there is no
second implementation of the convergence math anywhere in the repo.

Three embedding modes share the one loop (DESIGN.md §10):

  mode='pic'         EXACTLY the paper's per-vector Algorithm 1/2 loop
                     (lines 6-15): each column carries its own delta and
                     acceleration-based stopping flag, and a converged
                     column is frozen (its value and delta stop updating)
                     while the remaining columns keep iterating. A column's
                     trajectory is identical to what a dedicated
                     single-vector loop would have produced — the batching
                     changes the cost model, not the math.
  mode='orthogonal'  block/subspace iteration: column 0 keeps the classic
                     pinned PIC trajectory (bitwise — deflation target),
                     while columns 1..r-1 are Cholesky-QR re-orthonormalized
                     against it and each other every ``qr_every`` sweeps, so
                     they converge to the successive invariant-subspace
                     directions of W instead of all collapsing onto the
                     dominant one. Block columns are NOT frozen (freezing a
                     coupled subspace breaks its convergence); their done
                     flags latch the first eps-crossing for reporting.
  ensemble           :func:`ensemble_power_iteration` snapshots the classic
                     mode='pic' block at geometrically spaced diffusion
                     times and returns the stack — a multiscale embedding.

The Gram products that price the re-orthonormalization go through
``op.gram`` (locally the Pallas tall-skinny Gram kernel or its jnp oracle)
and are finished across chunks by ``op.sum``, so the sharded engines run
the identical block algebra.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .health import COL_MAXITER, COL_NONFINITE, COL_STALLED, COL_ZERO

EMBEDDINGS = ("pic", "orthogonal", "ensemble")

#: sweeps without a strict improvement of a column's acceleration statistic
#: before COL_STALLED latches (periodic/oscillating trajectories never
#: improve; slowly-converging ones improve every sweep) — diagnostic only,
#: the stall latch never stops or alters the iteration
STALL_PATIENCE = 10


def _identity(x):
    return x


def _gram_jnp(v):
    """Local-chunk Gram VᵀV in f32 — the default (oracle-math) binding;
    operator builders rebind to the Pallas tall-skinny kernel."""
    v32 = v.astype(jnp.float32)
    return v32.T @ v32


@dataclass(frozen=True)
class PowerOperator:
    """One degree-normalized sweep of A plus its reduction binding.

    Attributes:
      matmat: maps the local (n_loc, r) chunk of V to the local chunk of
        (A V) / d — ONE sweep of A however realized. Any gathering the
        realization needs (e.g. replicating V across a mesh before a
        stripe mat-mat) happens inside.
      degree: the local (n_loc,) degree chunk backing the sweep (v0 seed
        and diagnostics; None for bare-callable wrapping).
      sum: finishes a cross-chunk sum of an already-locally-reduced value
        (identity locally; ``psum`` over mesh axes when sharded).
      max: same for max (identity / ``pmax``).
      all_gather: maps a local (n_loc, ...) chunk to the global (n, ...)
        array (identity locally; tiled ``all_gather`` when sharded).
      gram: maps the local (n_loc, r) chunk to its LOCAL (r, r) Gram
        VᵀV partial; ``sum`` finishes the cross-chunk combine. Defaults to
        the jnp oracle math; operator builders bind the Pallas kernel.
      matmat_t: maps the local (n_loc, r) chunk of V to the local chunk of
        Aᵀ V — UNNORMALIZED, positivity-only semantics: the symmetrized
        reachability probe (core/health.py) unions its sign pattern with
        the forward sweep's to walk the kNN graph's reverse edges. Bound
        only by builders of truncated specs (the only graphs that can be
        asymmetric); None means "A is symmetric, forward reach suffices".
    """
    matmat: Callable[[jax.Array], jax.Array]
    degree: jax.Array | None = None
    sum: Callable[[jax.Array], jax.Array] = field(default=_identity)
    max: Callable[[jax.Array], jax.Array] = field(default=_identity)
    all_gather: Callable[[jax.Array], jax.Array] = field(default=_identity)
    gram: Callable[[jax.Array], jax.Array] = field(default=_gram_jnp)
    matmat_t: Callable[[jax.Array], jax.Array] | None = None


def as_operator(op) -> PowerOperator:
    """Wrap a bare ``matmat`` callable as a local (single-chunk) operator."""
    if isinstance(op, PowerOperator):
        return op
    return PowerOperator(matmat=op)


def orthonormalize_block(op, v):
    """Cholesky-QR of the (n_loc, r) block with column 0 pinned.

    G = VᵀV (global: local Gram finished by ``op.sum``) = LLᵀ, Q = VL⁻ᵀ —
    column j of Q is column j of V orthogonalized against all earlier
    columns and L2-normalized (thin QR). Column 0 is returned UNTOUCHED
    (deflation-style pinning: the classic degree-seeded PIC trajectory is
    the block's first basis vector, bitwise), which only drops Q's column-0
    rescale — orthogonality of the later columns against it is unaffected.
    All chunks compute the same replicated (r, r) factor, so the transform
    is chunk-local after one ``op.sum``.

    A numerically singular Gram (columns momentarily aligned — possible
    with ``qr_every`` > 1 on a fast-mixing graph) makes the f32 Cholesky
    non-finite; that step's re-orthonormalization is SKIPPED (the block
    passes through unchanged) and the next one retries after the power
    sweep re-mixes the columns. The skip predicate is computed on ``ell``
    — a REPLICATED value (every chunk factors the same global G) — so all
    chunks of a sharded run make the identical apply/skip decision; a
    chunk-local test on the transformed rows could diverge per chunk and
    silently mix QR'd and raw chunks of one global state. The guard costs
    nothing on the healthy path — the selected values are bitwise the
    factored ones.
    """
    g = op.sum(op.gram(v))                                       # (r, r)
    ell = jnp.linalg.cholesky(g)
    q = jax.scipy.linalg.solve_triangular(ell, v.T, lower=True).T
    out = jnp.concatenate([v[:, :1], q[:, 1:]], axis=1)
    return jnp.where(jnp.all(jnp.isfinite(ell)), out, v)


def subspace_residual(op, v, u):
    """Relative invariant-subspace residual ||U − VΛ||_F / ||U||_F with
    U = W V (the sweep output) and Λ the least-squares Rayleigh block
    (VᵀV)⁻¹VᵀU — the ||AQ − QΛ||-style stopping statistic of the
    orthogonal embedding mode (DESIGN.md §11).

    One Gram of the (n_loc, 2r) concatenation [V | U] supplies every term
    (the existing tall-skinny Gram kernel; ``op.sum`` finishes the
    cross-chunk combine, so the sharded value is the single-device one):

        ||U − VΛ||²_F = tr(Gᵤᵤ) − tr(Gᵥᵤᵀ Λ)

    exact for any V (the pinned block is orthonormal only up to column 0's
    free scale, which the normal-equations solve absorbs).
    """
    r = v.shape[1]
    g = op.sum(op.gram(jnp.concatenate([v, u], axis=1)))       # (2r, 2r)
    gvv, gvu, guu = g[:r, :r], g[:r, r:], g[r:, r:]
    lam = jnp.linalg.solve(gvv, gvu)
    denom = jnp.trace(guu)
    res2 = denom - jnp.trace(gvu.T @ lam)
    rel = jnp.sqrt(jnp.maximum(res2, 0.0) / jnp.maximum(denom, 1e-30))
    # a singular Gram (columns momentarily aligned) solves to non-finite;
    # report "not converged" and let the next QR re-mix, mirroring the
    # orthonormalize_block skip guard. A zero U (all-zero columns after a
    # dead sweep) makes the statistic 0/0 -> 0 — a FALSE "converged"; the
    # denom > 0 gate reports inf instead so a dead block can never
    # satisfy the residual rule.
    return jnp.where(jnp.isfinite(rel) & (denom > 0), rel, jnp.inf)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PowerCarry:
    """The FULL convergence-loop carry — everything the engine threads
    through one sweep, as one checkpointable pytree (DESIGN.md §14).

    A run interrupted after any sweep resumes bitwise-identically from
    this value: the loop body is a pure function of (carry, operator), so
    exporting the carry (``train/checkpoint.py``), restoring it, and
    continuing with :func:`power_iteration_segment` replays EXACTLY the
    trajectory the uninterrupted loop would have produced — same
    eps-crossings, same health latches, same per-column counters.
    """
    t: jax.Array        # () int32 — completed sweeps
    v: jax.Array        # (n_loc, r) — the engine state block
    delta: jax.Array    # (n_loc, r) — |v_t − v_{t−1}| (delta_0 = v_0)
    done: jax.Array     # (r,) bool — per-column convergence latches
    t_cols: jax.Array   # (r,) int32 — per-column iteration counters
    snaps: jax.Array    # (n_loc, r, S) — ensemble snapshot stack (S = 0
    #                     outside embedding='ensemble')
    status: jax.Array   # (r,) int32 — COL_* health latches
    best: jax.Array     # (r,) f32 — best acceleration seen (stall rule)
    since: jax.Array    # (r,) int32 — sweeps since ``best`` improved


def _carry_state(carry: PowerCarry) -> tuple:
    """The raw while_loop 9-tuple (kept a plain tuple inside the loop so
    the traced jaxpr is byte-identical to the historical one)."""
    return (carry.t, carry.v, carry.delta, carry.done, carry.t_cols,
            carry.snaps, carry.status, carry.best, carry.since)


def _init_state(v0, n_snapshots: int) -> tuple:
    """The sweep-0 loop state — the ONE construction both the monolithic
    loop and :func:`init_power_carry` use, so a segmented run starts from
    exactly the uninterrupted run's initial state."""
    r = v0.shape[1]
    return (
        jnp.int32(0), v0, v0,                      # delta_0 <- v_0 (line 1)
        jnp.zeros((r,), bool), jnp.zeros((r,), jnp.int32),
        jnp.zeros(v0.shape + (n_snapshots,), v0.dtype),
        jnp.zeros((r,), jnp.int32),                # status
        jnp.full((r,), jnp.inf, jnp.float32),      # best accel (stall)
        jnp.zeros((r,), jnp.int32),                # sweeps since improved
    )


def init_power_carry(v0, n_snapshots: int = 0) -> PowerCarry:
    """The sweep-0 :class:`PowerCarry` for an (n_loc, r) start block.
    ``n_snapshots`` sizes the ensemble snapshot stack (0 = none)."""
    return PowerCarry(*_init_state(v0, n_snapshots))


def power_carry_like(n, r, n_snapshots: int = 0, dtype=jnp.float32):
    """ShapeDtypeStruct pytree of the carry for a global (n, r) state —
    the ``like`` argument checkpoint restore needs (DESIGN.md §14)."""
    sds = jax.ShapeDtypeStruct
    return PowerCarry(
        t=sds((), jnp.int32), v=sds((n, r), dtype), delta=sds((n, r), dtype),
        done=sds((r,), jnp.bool_), t_cols=sds((r,), jnp.int32),
        snaps=sds((n, r, n_snapshots), dtype), status=sds((r,), jnp.int32),
        best=sds((r,), jnp.float32), since=sds((r,), jnp.int32))


def _validate_loop_args(mode, qr_every, residual_tol, r):
    """Shared host-side argument checks of the loop and its segmented
    form. Returns (block, residual) — the static routing flags."""
    if mode not in ("pic", "orthogonal"):
        raise ValueError(
            f"unknown power-loop mode {mode!r} (expected 'pic' or "
            "'orthogonal'; 'ensemble' is ensemble_power_iteration)")
    if qr_every < 1:
        raise ValueError(f"qr_every must be >= 1, got {qr_every}")
    if residual_tol is not None and not float(residual_tol) > 0.0:
        raise ValueError(
            f"residual_tol must be > 0 (a relative residual), got "
            f"{residual_tol}")
    block = mode == "orthogonal" and r > 1
    residual = residual_tol is not None
    if residual and not block:
        raise ValueError(
            "residual_tol needs a QR-coupled block (mode='orthogonal' "
            f"with r > 1); got mode={mode!r}, r={r} — the rule could "
            "never arm")
    return block, residual


def _run_loop_state(op, state, eps, bound, mode, qr_every, snapshot_iters,
                    residual_tol=None, collect_health=True):
    """Advance a raw loop state until ``t >= bound`` or every column is
    done — the while_loop shared by the monolithic loop (bound = max_iter,
    a Python int, compiling the historical jaxpr unchanged) and the
    segmented form (bound = a traced stop sweep). The BODY is the one
    function in the repo that defines a sweep; segmentation only changes
    where the while_loop stops, never what a sweep computes — that is the
    whole bitwise-resume guarantee (DESIGN.md §14).
    """
    block, residual = _validate_loop_args(
        mode, qr_every, residual_tol, state[1].shape[1])
    op = as_operator(op)
    r = state[1].shape[1]

    def cond(state):
        t, _v, _delta, done = state[:4]
        return jnp.logical_and(t < bound, jnp.logical_not(jnp.all(done)))

    def body(state):
        t, v, delta, done, t_cols, snaps, status, best, since = state
        u = op.matmat(v)                                        # (n_loc, r)
        l1 = op.sum(jnp.sum(jnp.abs(u), axis=0))                # (r,)
        v_next = u / jnp.maximum(l1, 1e-30)[None, :]
        fault = jnp.zeros((r,), bool)
        if collect_health:
            # per-column fault latches: exact-zero L1 mass (the column has
            # no signal left — e.g. an all-zero v0 column, previously a
            # hidden 0/0 frozen forever without reporting) and NaN/Inf
            # (non-finite input or a corrupted sweep). A faulted column is
            # zeroed so the damage cannot leak into other columns through
            # a later QR, and latched done. Both tests read the ALREADY
            # computed (and already cross-chunk-summed) l1 — a NaN/Inf
            # anywhere in the column propagates into its absolute sum, so
            # no additional (n, r) reduction is introduced (adding one
            # perturbs XLA's fusion of the existing loop reductions enough
            # to shift boundary eps-crossings in interpret mode, breaking
            # the local/sharded parity discipline) and every shard latches
            # identically off the replicated value.
            zero_col = l1 <= 0.0                                # (r,)
            bad_col = jnp.logical_not(jnp.isfinite(l1))         # (r,)
            fault = (zero_col | bad_col) & ~done
            v_next = jnp.where(fault[None, :], 0.0, v_next)
            status = (status
                      | jnp.where(zero_col & fault, COL_ZERO, 0)
                      | jnp.where(bad_col & fault, COL_NONFINITE, 0)
                      ).astype(jnp.int32)
        qr_now = (t + 1) % qr_every == 0
        if block:
            if qr_every == 1:
                v_next = orthonormalize_block(op, v_next)
            else:
                v_next = jax.lax.cond(
                    qr_now,
                    lambda vv: orthonormalize_block(op, vv),
                    lambda vv: vv, v_next)
        delta_next = jnp.abs(v_next - v)
        accel = op.max(jnp.max(jnp.abs(delta_next - delta), axis=0))  # (r,)
        # columns already done are frozen: keep prior value/delta, don't
        # count the iteration; columns converging NOW keep this update
        # (the per-vector loop applies the converging step before stopping).
        # In block mode only the pinned column 0 freezes — the QR-coupled
        # columns keep iterating (done latches the first crossing).
        freeze = done & (jnp.arange(r) == 0) if block else done
        v_next = jnp.where(freeze[None, :], v, v_next)
        delta_next = jnp.where(freeze[None, :], delta, delta_next)
        t_cols = t_cols + jnp.where(done, 0, 1).astype(jnp.int32)
        done = jnp.logical_or(done, accel <= eps)
        if collect_health:
            done = jnp.logical_or(done, fault)
            # stall detector: a column whose acceleration statistic has not
            # strictly improved on its best for STALL_PATIENCE sweeps is
            # flagged (periodic trajectories — e.g. a bipartite graph's
            # oscillation — repeat their accel values forever). Diagnostic
            # only: the flag never stops or alters the iteration.
            improved = accel < best
            since = jnp.where(done | improved, 0, since + 1).astype(
                jnp.int32)
            best = jnp.minimum(best, accel)
            status = (status | jnp.where(
                ~done & (since >= STALL_PATIENCE), COL_STALLED, 0)
            ).astype(jnp.int32)
        if residual:
            # priced at QR cadence only; gating on done[0] keeps column 0's
            # classic n_iter/converged stats bitwise (the subspace never
            # stops the loop before the pinned trajectory has finished)
            rel = jax.lax.cond(
                qr_now & done[0],
                lambda: subspace_residual(op, v, u),
                lambda: jnp.float32(jnp.inf))
            done = jnp.logical_or(done, rel <= residual_tol)
        for j, s in enumerate(snapshot_iters):
            snaps = snaps.at[:, :, j].set(
                jnp.where(t + 1 == s, v_next, snaps[:, :, j]))
        return (t + 1, v_next, delta_next, done, t_cols, snaps,
                status, best, since)

    return jax.lax.while_loop(cond, body, state)


def _power_loop(op, v0, eps, max_iter, mode, qr_every, snapshot_iters,
                residual_tol=None, collect_health=True):
    """The one convergence loop behind every embedding mode. Returns
    (t, V, t_cols, done, snaps, status) with snaps (n_loc, r, S) holding
    the block at each requested iteration count (S = len(snapshot_iters))
    and status the (r,) int32 per-column COL_* health bitmask.

    ``residual_tol`` (static; block mode only) arms the subspace residual
    stopping rule: on every QR step, once the pinned column 0 has converged
    by its classic acceleration rule, a relative residual <= residual_tol
    latches ALL remaining columns done — the block stops at subspace
    convergence instead of running to max_iter. None (the default) compiles
    the exact PR-3 loop.

    ``collect_health`` (static) arms the divergence latches: a column whose
    L1 mass hits exact zero (COL_ZERO) or that produced a NaN/Inf
    (COL_NONFINITE) is zeroed and latched done — the fault can never
    propagate into other columns through a later QR — and a column whose
    acceleration statistic stops improving for STALL_PATIENCE sweeps is
    flagged COL_STALLED (diagnostic only). On a clean run every latch
    predicate is False, so the selected values are bitwise the unlatched
    ones — the health layer is a pure observer (DESIGN.md §12).
    ``collect_health=False`` compiles the loop without the latch
    computations (the benchmark baseline for pricing them).
    """
    state = _init_state(v0, len(snapshot_iters))
    (t, v, _delta, done, t_cols, snaps,
     status, _best, _since) = _run_loop_state(
        op, state, eps, max_iter, mode, qr_every, snapshot_iters,
        residual_tol=residual_tol, collect_health=collect_health)
    if collect_health:
        status = (status | jnp.where(~done, COL_MAXITER, 0)).astype(
            jnp.int32)
    return t, v, t_cols, done, snaps, status


def power_iteration_segment(op, carry: PowerCarry, eps, stop, *, mode="pic",
                            qr_every=1, snapshot_iters=(),
                            residual_tol=None,
                            collect_health=True) -> PowerCarry:
    """Advance the convergence carry by a bounded segment: run sweeps
    until ``carry.t >= stop`` or every column is done, and return the new
    carry. ``stop`` may be a traced scalar (one compiled segment program
    serves every boundary) — the loop BODY is byte-identical to the
    monolithic loop's, so a run split into segments (with the carry
    round-tripped through a checkpoint between them) reproduces the
    uninterrupted trajectory bitwise (DESIGN.md §14). Apply
    :func:`finalize_power_carry` once ``stop`` has reached max_iter or
    all columns are done.
    """
    state = _run_loop_state(
        op, _carry_state(carry), eps, stop, mode, qr_every, snapshot_iters,
        residual_tol=residual_tol, collect_health=collect_health)
    return PowerCarry(*state)


def finalize_power_carry(carry: PowerCarry, *, collect_health=True):
    """Close out a finished carry exactly as the monolithic loop does on
    exit: latch COL_MAXITER on still-unconverged columns. Returns the
    ``(t, v, t_cols, done, snaps, status)`` tuple of ``_power_loop``."""
    status = carry.status
    if collect_health:
        status = (status | jnp.where(~carry.done, COL_MAXITER, 0)).astype(
            jnp.int32)
    return (carry.t, carry.v, carry.t_cols, carry.done, carry.snaps, status)


def backfill_snapshots(snaps, v, t, snapshot_iters):
    """Fill ensemble snapshot slots the loop never reached (early exit
    before their diffusion time) with the final frozen block — the ONE
    implementation of the backfill both the monolithic ensemble loop and
    the segmented finalize use."""
    written = jnp.asarray(snapshot_iters, jnp.int32) <= t         # (S,)
    return jnp.where(written[None, None, :], snaps, v[:, :, None])


def batched_power_iteration(op, v0, eps, max_iter, *, mode="pic",
                            qr_every=1, residual_tol=None,
                            collect_health=True, return_status=False):
    """Run the truncated power iteration on batched state.

    Args:
      op: a :class:`PowerOperator`, or a bare callable mapping V (n, r) to
        (A V) / d (wrapped as a local operator).
      v0: (n_loc, r) initial vectors — the caller's local row chunk of the
        global (n, r) state (the whole state on a single device).
      eps: the paper's acceleration threshold (typically 1e-5 / n).
      max_iter: iteration cap.
      mode: 'pic' (classic per-column loop, frozen columns) or
        'orthogonal' (block iteration, column 0 pinned — see module doc).
        With r = 1 both modes are the identical classic loop, bitwise.
      qr_every: re-orthonormalization period in sweeps ('orthogonal' only).
      residual_tol: arm the subspace residual stopping rule ('orthogonal'
        with r > 1 only): once column 0 has converged classically, a
        relative ||WV − VΛ|| residual <= residual_tol on a QR step stops
        the whole block (None — the default — runs the PR-3 loop bitwise).
      collect_health: arm the per-column divergence latches (zero-mass,
        non-finite, stall — see ``_power_loop``); False compiles the loop
        without them (the guard-overhead benchmark baseline).
      return_status: also return the (r,) int32 COL_* status bitmask as a
        fourth element (kept opt-in so the historical 3-tuple unpacking
        keeps working).

    Returns:
      (V, t_cols, done): final local (n_loc, r) state, per-column iteration
      counts (r,) int32, and per-column convergence flags (r,) bool — plus
      the (r,) status mask when ``return_status``. The counts/flags are
      replicated across chunks; gather V with ``op.all_gather`` if the
      full embedding is needed.
    """
    _t, v, t_cols, done, _snaps, status = _power_loop(
        op, v0, eps, max_iter, mode, qr_every, (),
        residual_tol=residual_tol, collect_health=collect_health)
    if return_status:
        return v, t_cols, done, status
    return v, t_cols, done


def default_snapshot_iters(max_iter, n_snapshots=4):
    """Geometrically spaced diffusion times max_iter/2^(S-1-j), ascending,
    deduplicated — the default ensemble schedule."""
    iters: list[int] = []
    for j in range(n_snapshots):
        t = max(1, max_iter // (2 ** (n_snapshots - 1 - j)))
        if not iters or t > iters[-1]:
            iters.append(t)
    return tuple(iters)


def ensemble_power_iteration(op, v0, eps, max_iter, *,
                             snapshot_iters: Sequence[int] | None = None):
    """Diffusion-time ensemble: the classic mode='pic' loop, with the block
    captured at each of ``snapshot_iters`` (static, ascending; default
    geometric in ``max_iter``). Per-column freezing means the state is
    constant once every column has converged, so snapshots past an early
    exit are backfilled with the final (frozen) block — no extra sweeps.

    Returns (snaps, t_cols, done, v, status): the (n_loc, r, S) snapshot
    stack plus the loop's ACTUAL final state v (== snaps[:, :, -1] whenever
    the last snapshot time is max_iter or past the exit; later if a custom
    schedule ends before convergence) and the (r,) COL_* status mask.
    Flatten snaps to the k-means embedding with :func:`ensemble_embedding`.
    """
    snapshot_iters = tuple(
        int(s) for s in (snapshot_iters if snapshot_iters is not None
                         else default_snapshot_iters(max_iter)))
    if not snapshot_iters or list(snapshot_iters) != sorted(
            set(snapshot_iters)):
        raise ValueError(
            f"snapshot_iters must be non-empty strictly ascending ints, "
            f"got {snapshot_iters!r}")
    if snapshot_iters[0] < 1 or snapshot_iters[-1] > max_iter:
        raise ValueError(
            f"snapshot_iters {snapshot_iters!r} must lie in [1, max_iter="
            f"{max_iter}]")
    t, v, t_cols, done, snaps, status = _power_loop(
        op, v0, eps, max_iter, "pic", 1, snapshot_iters)
    snaps = backfill_snapshots(snaps, v, t, snapshot_iters)
    return snaps, t_cols, done, v, status


def run_power_embedding(op, v0, eps, max_iter, *, embedding="pic",
                        qr_every=1, snapshot_iters=None, residual_tol=None):
    """Run the engine in the requested embedding mode — the one helper every
    entry point (local, sharded, oracle) calls, so mode routing exists once.

    Returns (v, t_cols, done, emb, status): the final local (n_loc, r)
    state, the per-column stats, the LOCAL chunk of the matrix to cluster
    (the state itself for 'pic'/'orthogonal'; the (n_loc, r·S) snapshot
    concatenation for 'ensemble'), and the (r,) int32 COL_* health mask.
    """
    if embedding not in EMBEDDINGS:
        raise ValueError(
            f"unknown embedding {embedding!r} (expected one of {EMBEDDINGS})")
    if residual_tol is not None and embedding != "orthogonal":
        raise ValueError(
            "residual_tol arms the subspace residual stopping rule of "
            "embedding='orthogonal' only")
    if embedding == "ensemble":
        snaps, t_cols, done, v, status = ensemble_power_iteration(
            op, v0, eps, max_iter, snapshot_iters=snapshot_iters)
        return v, t_cols, done, ensemble_embedding(snaps), status
    v, t_cols, done, status = batched_power_iteration(
        op, v0, eps, max_iter, mode=embedding, qr_every=qr_every,
        residual_tol=residual_tol, return_status=True)
    return v, t_cols, done, v, status


def ensemble_embedding(snaps):
    """Flatten an (n, r, S) snapshot stack to the (n, r·S) k-means
    embedding (column order c·S + s — the ONE canonical layout both the
    local and sharded paths use, so their embeddings agree column-for-
    column)."""
    return snaps.reshape(snaps.shape[0], -1)


def random_start_vectors(krand, n, n_vectors, dtype=jnp.float32):
    """(n, r-1) L1-normalized uniform random starts — columns 1..r-1 of the
    engine state (Lin & Cohen's multi-vector extension, O3). The single
    source of this recipe: single-host and distributed paths must draw
    bit-identical columns for their trajectories to agree."""
    if n_vectors <= 1:
        return jnp.zeros((n, 0), dtype)
    u0 = jax.random.uniform(krand, (n_vectors - 1, n), dtype)
    u0 = u0 / jnp.sum(u0, axis=1, keepdims=True)
    return u0.T


def init_power_vectors(krand, d, n_vectors, dtype=None):
    """Build the (n, r) start state: column 0 is the paper's degree start
    v_0 = D / sum(D) (Algorithm 2 lines 4-5); the rest are random starts."""
    dtype = dtype or d.dtype
    v0 = (d / jnp.maximum(jnp.sum(d), 1e-30)).astype(dtype)
    return jnp.concatenate(
        [v0[:, None], random_start_vectors(krand, d.shape[0], n_vectors, dtype)],
        axis=1)


def init_power_vectors_local(d_loc, u0t_loc, sum_fn=_identity, dtype=None):
    """Local-chunk variant of :func:`init_power_vectors`: column 0 is the
    degree start normalized by the GLOBAL degree mass (``sum_fn`` finishes
    the cross-chunk sum — identity locally, ``psum`` when sharded) and the
    remaining columns are the caller's local slice of the replicated random
    starts, so every chunk seeds exactly the single-device state."""
    dtype = dtype or d_loc.dtype
    dsum = sum_fn(jnp.sum(d_loc))
    v0 = (d_loc / jnp.maximum(dsum, 1e-30)).astype(dtype)
    return jnp.concatenate([v0[:, None], u0t_loc.astype(dtype)], axis=1)


def standardize_columns(v):
    """Per-column zero-mean / unit-variance rescale of the (n, r) embedding."""
    mu = jnp.mean(v, axis=0, keepdims=True)
    sd = jnp.maximum(jnp.std(v, axis=0, keepdims=True), 1e-30)
    return (v - mu) / sd
