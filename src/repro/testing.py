"""Shared multi-device subprocess harness for tests and benchmarks.

Host-platform virtual devices are fixed by XLA_FLAGS *before* jax imports,
so anything that wants an N-device CPU mesh must run in a fresh
interpreter while the parent process keeps its single-device view. This is
the ONE implementation of that recipe — tests/conftest.py and
benchmarks/bench_distributed.py both use it.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

#: repo src/ root (this file lives at src/repro/testing.py)
SRC_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_mesh_subprocess(code: str, *, devices: int = 8,
                        timeout: int = 1200) -> str:
    """Run ``code`` in a fresh interpreter with ``devices`` virtual CPU
    devices (XLA_FLAGS prelude prepended; PYTHONPATH gains src/). Returns
    captured stdout; raises RuntimeError with the stderr tail on a
    non-zero exit."""
    prelude = (
        f'import os\n'
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={devices}"\n'
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_ROOT + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"mesh subprocess failed (exit {out.returncode}):\n"
            f"{out.stderr[-3000:]}")
    return out.stdout
