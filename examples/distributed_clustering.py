"""Distributed GPIC on a multi-device mesh (the paper's multi-GPU future
work, realized with shard_map).

Runs on 8 virtual CPU devices; the identical code shards over the
(pod, data) axes of the production mesh on real hardware.

    PYTHONPATH=src python examples/distributed_clustering.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import adjusted_rand_index, pic_reference  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    distributed_gpic, distributed_gpic_matrix_free, shard_points)
from repro.data import dataset_by_name  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("data",))
    print(f"mesh: {mesh.shape}")

    # explicit-A path: row-striped affinity, O(n) collectives per step
    x, y, k = dataset_by_name("three_circles", 1600, seed=0)
    xs = shard_points(x, mesh, "data")
    res = distributed_gpic(xs, k, key=jax.random.key(1), mesh=mesh,
                           affinity_kind="rbf", sigma=0.3, max_iter=300)
    ari = adjusted_rand_index(y, np.asarray(res.labels))
    ref = pic_reference(jnp.asarray(x), k, key=jax.random.key(1),
                        affinity_kind="rbf", sigma=0.3, max_iter=300)
    err = float(jnp.max(jnp.abs(ref.embedding - res.embedding)))
    print(f"explicit-A : ARI={ari:.3f} iters={int(res.n_iter)} "
          f"| single-device parity err={err:.2e}")

    # matrix-free path: O(m) collectives per step — the 1000-node layout
    x, y, k = dataset_by_name("gaussians", 80_000, seed=0)
    xs = shard_points(x, mesh, "data")
    res = distributed_gpic_matrix_free(
        xs, 3, key=jax.random.key(1), mesh=mesh,
        affinity_kind="cosine_shifted", max_iter=50)
    print(f"matrix-free: n=80k iters={int(res.n_iter)} "
          f"labels on host: {np.bincount(np.asarray(res.labels))}")


if __name__ == "__main__":
    main()
