"""Distributed GPIC on a multi-device mesh (the paper's multi-GPU future
work, realized with shard_map over the operator pipeline — DESIGN.md §9).

Runs on 8 virtual CPU devices; the identical code shards over the
(pod, data) axes of the production mesh on real hardware. All three
sharded paths run the SAME convergence engine as the single-device
entry points — only the PowerOperator binding changes.

    PYTHONPATH=src python examples/distributed_clustering.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    GPICConfig, adjusted_rand_index, pic_reference, run_gpic)
from repro.core.distributed import shard_points  # noqa: E402
from repro.data import dataset_by_name  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("data",))
    print(f"mesh: {mesh.shape}")

    # explicit path: row-striped Pallas A build, O(n r) collectives per step
    x, y, k = dataset_by_name("three_circles", 1600, seed=0)
    xs = shard_points(x, mesh, "data")
    cfg = GPICConfig(mesh=mesh, shard_axes="data", affinity_kind="rbf",
                     sigma=0.3, max_iter=300)
    res = run_gpic(xs, k, cfg, key=jax.random.key(1))
    ari = adjusted_rand_index(y, np.asarray(res.labels))
    ref = pic_reference(jnp.asarray(x), k, key=jax.random.key(1),
                        affinity_kind="rbf", sigma=0.3, max_iter=300)
    err = float(jnp.max(jnp.abs(ref.embedding - res.embedding)))
    print(f"explicit-A : ARI={ari:.3f} iters={int(res.n_iter)} "
          f"| single-device parity err={err:.2e}")

    # streaming ring: A-free AND gather-free — O(n·m/P) per device, every
    # affinity kind. The production configuration.
    res_s = run_gpic(xs, k, cfg.with_(engine="streaming"),
                     key=jax.random.key(1))
    sd = run_gpic(jnp.asarray(x), k, cfg.with_(mesh=None, engine="streaming"),
                  key=jax.random.key(1))
    same = bool((np.asarray(res_s.labels) == np.asarray(sd.labels)).all())
    print(f"streaming  : iters={int(res_s.n_iter)} "
          f"| labels identical to single-device engine: {same}")

    # orthogonal embedding on the mesh: the QR's Gram partials psum through
    # the operator binding, so the sharded block clusters identically to
    # the single-device engine (DESIGN.md §10)
    cfg_o = cfg.with_(n_vectors=2, embedding="orthogonal", max_iter=400)
    res_o = run_gpic(xs, k, cfg_o, key=jax.random.key(1))
    sd_o = run_gpic(jnp.asarray(x), k, cfg_o.with_(mesh=None),
                    key=jax.random.key(1))
    same_o = bool((np.asarray(res_o.labels) == np.asarray(sd_o.labels)).all())
    ari_o = adjusted_rand_index(y, np.asarray(res_o.labels))
    print(f"orthogonal : ARI={ari_o:.3f} (2-col block separates the rings "
          f"the 1-D embedding collapses) | labels identical to "
          f"single-device: {same_o}")

    # matrix-free path: O(m) collectives per step — the 1000-node layout
    x, y, k = dataset_by_name("gaussians", 80_000, seed=0)
    xs = shard_points(x, mesh, "data")
    cfg = GPICConfig(engine="matrix_free", mesh=mesh, shard_axes="data",
                     affinity_kind="cosine_shifted", max_iter=50)
    res = run_gpic(xs, 3, cfg, key=jax.random.key(1))
    print(f"matrix-free: n=80k iters={int(res.n_iter)} "
          f"labels on host: {np.bincount(np.asarray(res.labels))}")


if __name__ == "__main__":
    main()
