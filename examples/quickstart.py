"""Quickstart: cluster the paper's synthetic datasets with GPIC.

One config object, one entry point — ``run_gpic(x, k, GPICConfig(...))``
routes to the right operator-backed engine (see DESIGN.md §9).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AffinitySpec,
    GPICConfig,
    adjusted_rand_index,
    jaccard_index,
    run_gpic,
)
from repro.data import dataset_by_name


def main():
    print("GPIC quickstart — explicit-A (paper-faithful) pipeline")
    for name, sigma, nv in (("three_circles", 0.3, 1), ("cassini", 0.3, 2),
                            ("gaussians", 0.3, 1), ("smiley", 0.15, 1)):
        x, y, k = dataset_by_name(name, 1200, seed=0)
        cfg = GPICConfig(affinity_kind="rbf", sigma=sigma, max_iter=400,
                         n_vectors=nv)
        res = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        jac = jaccard_index(y, np.asarray(res.labels))
        print(f"  {name:15s} k={k}  iters={int(res.n_iter):3d} "
              f"ARI={ari:.3f} Jaccard={jac:.3f}")

    print("\nembedding modes on nested structure (three_circles, "
          "DESIGN.md §10):")
    x, y, k = dataset_by_name("three_circles", 1200, seed=0)
    for emb, nv in (("pic", 1), ("orthogonal", 2), ("ensemble", 1)):
        cfg = GPICConfig(affinity_kind="rbf", sigma=0.3, max_iter=400,
                         n_vectors=nv, embedding=emb)
        res = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        print(f"  embedding={res.embedding_mode:10s} r={nv} "
              f"embeddings{tuple(res.embeddings.shape)} ARI={ari:.3f}"
              + ("   <- separates all three rings" if emb == "orthogonal"
                 else ""))

    print("\nstreaming (A-free) engine on the same data — identical labels,"
          " no (n, n) allocation:")
    x, y, k = dataset_by_name("three_circles", 1200, seed=0)
    cfg = GPICConfig(affinity_kind="rbf", sigma=0.3, max_iter=400)
    res_e = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
    res_s = run_gpic(jnp.asarray(x), k, cfg.with_(engine="streaming"),
                     key=jax.random.key(1))
    same = bool((np.asarray(res_e.labels) == np.asarray(res_s.labels)).all())
    print(f"  three_circles explicit vs streaming: labels identical={same}")

    print("\naffinity-graph specs (DESIGN.md §11) — two_moons at sigma "
          "0.25, the dataset every dense mode leaves marginal (~0.5):")
    x, y, k = dataset_by_name("two_moons", 1200, seed=0)
    for tag, spec, rt in (
            ("dense rbf", AffinitySpec(kind="rbf", sigma=0.25), None),
            # knn_k ~ n/16 tracks the arc density (30 at n=480, 75 here);
            # residual_tol stops the block at subspace convergence instead
            # of max_iter
            ("kNN-truncated (k=n/16)",
             AffinitySpec(kind="rbf", sigma=0.25, knn_k=75), 1e-3)):
        cfg = GPICConfig(affinity=spec, max_iter=400, n_vectors=2,
                         embedding="orthogonal", residual_tol=rt)
        res = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        print(f"  {tag:24s} ARI={ari:.3f} "
              f"iters={np.asarray(res.n_iter_cols).tolist()}")

    print("\nadaptive local scaling — self-tuning bandwidths, NO sigma "
          "to choose (exp(-d^2/(s_i s_j)) from each point's scale_k-th "
          "neighbor):")
    spec = AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=25,
                        knn_k=75)
    for name in ("gaussians", "cassini"):
        x, y, k = dataset_by_name(name, 1200, seed=0)
        cfg = GPICConfig(affinity=spec, max_iter=400, n_vectors=2,
                         embedding="orthogonal")
        res = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        print(f"  {name:15s} adaptive+kNN ARI={ari:.3f}")

    print("\nmatrix-free GPIC (beyond-paper O2) at n=100,000:")
    x, y, k = dataset_by_name("gaussians", 100_000, seed=0)
    cfg = GPICConfig(engine="matrix_free", affinity_kind="cosine_shifted",
                     max_iter=50)
    res = run_gpic(jnp.asarray(x), 3, cfg, key=jax.random.key(1))
    print(f"  n=100k clustered in {int(res.n_iter)} power iterations "
          f"(A would be 40 GB; matrix-free uses ~1.6 MB)")


if __name__ == "__main__":
    main()
