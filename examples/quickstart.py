"""Quickstart: cluster the paper's synthetic datasets with GPIC.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adjusted_rand_index, gpic, gpic_matrix_free, jaccard_index
from repro.data import dataset_by_name


def main():
    print("GPIC quickstart — explicit-A (paper-faithful) pipeline")
    for name, sigma, nv in (("three_circles", 0.3, 1), ("cassini", 0.3, 2),
                            ("gaussians", 0.3, 1), ("smiley", 0.15, 1)):
        x, y, k = dataset_by_name(name, 1200, seed=0)
        res = gpic(jnp.asarray(x), k, key=jax.random.key(1),
                   affinity_kind="rbf", sigma=sigma, max_iter=400,
                   n_vectors=nv)
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        jac = jaccard_index(y, np.asarray(res.labels))
        print(f"  {name:15s} k={k}  iters={int(res.n_iter):3d} "
              f"ARI={ari:.3f} Jaccard={jac:.3f}")

    print("\nmatrix-free GPIC (beyond-paper O2) at n=100,000:")
    x, y, k = dataset_by_name("gaussians", 100_000, seed=0)
    res = gpic_matrix_free(jnp.asarray(x), 3, key=jax.random.key(1),
                           affinity_kind="cosine_shifted", max_iter=50)
    # gaussians defaults to k=4; use 3 angular clusters for cosine affinity
    x3, y3, _ = dataset_by_name("gaussians", 100_000, seed=0)
    print(f"  n=100k clustered in {int(res.n_iter)} power iterations "
          f"(A would be 40 GB; matrix-free uses ~1.6 MB)")


if __name__ == "__main__":
    main()
