"""GPIC as a first-class framework feature: spectral clustering of a trained
LM's token embeddings (ties the paper's algorithm to the LM substrate).

Trains a small LM briefly on the synthetic Zipf-Markov stream, then runs
matrix-free distributed-ready GPIC over the (vocab, d_model) embedding table
to find k embedding clusters (high-frequency function-token cluster vs tail
clusters emerge from the bigram structure).

    PYTHONPATH=src python examples/cluster_embeddings.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.core import gpic_matrix_free
from repro.data.tokens import SyntheticTokenStream
from repro.models import get_api
from repro.train import adamw_init, build_train_step


def main():
    cfg = get_config("stablelm-3b").replace(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=704, vocab_size=2048)
    tcfg = TrainConfig(seq_len=128, global_batch=8, learning_rate=2e-3,
                       warmup_steps=20, total_steps=150,
                       compute_dtype="float32", remat="none")
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0), cfg)
    opt = adamw_init(params)
    step = jax.jit(build_train_step(cfg, tcfg))
    stream = SyntheticTokenStream(cfg.vocab_size, seed=0)

    print("training a small LM (150 steps)...")
    for i in range(150):
        batch = {k: jnp.asarray(v)
                 for k, v in stream.batch_at(i, 8, 128).items()}
        params, opt, m = step(params, opt, batch)
        if i % 50 == 0:
            print(f"  step {i:3d} loss {float(m['loss']):.4f}")

    # rare tokens keep their random-init embeddings (no gradient signal) and
    # would form one degenerate blob — cluster the TRAINED head of the
    # Zipf distribution, where bigram structure has shaped the geometry
    top_n = 512
    emb = params["embed"]["tok"][:top_n]                # (top_n, d)
    print(f"clustering the {top_n} most-frequent token embeddings with GPIC "
          f"(matrix-free, k=6, 4 vectors)...")
    res = gpic_matrix_free(emb, 6, key=jax.random.key(1),
                           affinity_kind="cosine_shifted", max_iter=100,
                           n_vectors=4)
    labels = np.asarray(res.labels)
    counts = np.bincount(labels, minlength=6)
    print(f"  power iterations: {int(res.n_iter)}")
    print(f"  cluster sizes: {sorted(counts.tolist(), reverse=True)}")
    # Interpretation: after only 150 steps most embeddings are still near
    # their isotropic init (pairwise cosine ~0 -> near-uniform affinity), so
    # GPIC correctly reports one bulk cluster plus the handful of
    # heavy-gradient outlier tokens that have already moved. Train longer
    # (--steps 2000+) and the bulk fragments into bigram-role clusters.
    outliers = np.flatnonzero(labels != np.argmax(counts))
    print(f"  heavy-gradient outlier tokens split off: {outliers.tolist()}")


if __name__ == "__main__":
    main()
