"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU.

Uses the stablelm-3b family at width 512 (≈114M params), the synthetic
Zipf-Markov token stream, the full production train step (microbatching,
AdamW, grad clip, z-loss) and the restartable checkpointing loop.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.data.tokens import SyntheticTokenStream
from repro.models import get_api
from repro.train import adamw_init, build_train_step
from repro.train.fault_tolerance import RestartableLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param member of the stablelm family
    cfg = get_config("stablelm-3b").replace(
        n_layers=10, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=50304)
    tcfg = TrainConfig(seq_len=args.seq, global_batch=args.batch,
                       learning_rate=1e-3, warmup_steps=30,
                       total_steps=args.steps, compute_dtype="float32",
                       remat="none")

    api = get_api(cfg)
    params = api.init_params(jax.random.key(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params ({cfg.n_layers}L d={cfg.d_model})")

    opt = adamw_init(params)
    step_jit = jax.jit(build_train_step(cfg, tcfg))
    stream = SyntheticTokenStream(cfg.vocab_size, seed=0)

    def step_fn(state, batch):
        p, o = state
        p, o, m = step_jit(p, o, batch)
        return (p, o), m

    def data_fn(step):
        b = stream.batch_at(step, args.batch, args.seq)
        return {k: jnp.asarray(v) for k, v in b.items()}

    loop = RestartableLoop(step_fn, data_fn, args.ckpt_dir, ckpt_every=100)
    t0 = time.time()
    _, step, log = loop.run((params, opt), args.steps)
    dt = time.time() - t0

    for rec in log[::25]:
        print(f"  step {rec['step']:4d}  loss {rec['loss']:.4f}  "
              f"{rec['sec']*1e3:.0f} ms/step")
    print(f"trained {step} steps in {dt:.1f}s — "
          f"loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}")
    assert log[-1]["loss"] < log[0]["loss"], "loss must decrease"


if __name__ == "__main__":
    main()
