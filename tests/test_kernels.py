"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.affinity import row_normalize_features
from repro.kernels import ops, ref

SHAPES_N_M = [(64, 2), (100, 3), (256, 2), (300, 7), (517, 16), (1024, 2)]
TILES = [(128, 128), (256, 256), (128, 256)]


class TestAffinityKernel:
    @pytest.mark.parametrize("n,m", SHAPES_N_M)
    @pytest.mark.parametrize("kind", ["cosine", "cosine_shifted", "rbf"])
    def test_shape_sweep(self, n, m, kind):
        x = jax.random.normal(jax.random.key(n * m), (n, m))
        inp = x if kind == "rbf" else row_normalize_features(x)
        a_k, d_k = ops.affinity_and_degree(inp, kind=kind, sigma=0.8)
        a_r, d_r = ref.affinity_and_degree_ref(inp, kind=kind, sigma=0.8)
        assert a_k.shape == (n, n) and d_k.shape == (n,)
        np.testing.assert_allclose(a_k, a_r, atol=1e-5)
        np.testing.assert_allclose(d_k, d_r, atol=1e-3, rtol=1e-5)

    @pytest.mark.parametrize("tm,tn", TILES)
    def test_tile_sweep(self, tm, tn):
        x = row_normalize_features(jax.random.normal(jax.random.key(0), (400, 4)))
        a_k, d_k = ops.affinity_and_degree(x, kind="cosine_shifted", tm=tm, tn=tn)
        a_r, d_r = ref.affinity_and_degree_ref(x, kind="cosine_shifted")
        np.testing.assert_allclose(a_k, a_r, atol=1e-5)
        np.testing.assert_allclose(d_k, d_r, atol=1e-3, rtol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        x = row_normalize_features(
            jax.random.normal(jax.random.key(1), (200, 5))
        ).astype(dtype)
        a_k, d_k = ops.affinity_and_degree(x, kind="cosine_shifted")
        a_r, d_r = ref.affinity_and_degree_ref(x, kind="cosine_shifted")
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(a_k, np.float32), a_r, atol=tol)
        np.testing.assert_allclose(d_k, d_r, atol=max(tol * 200, 1e-3), rtol=tol)

    def test_diagonal_is_zero(self):
        x = row_normalize_features(jax.random.normal(jax.random.key(2), (130, 3)))
        a_k, _ = ops.affinity_and_degree(x, kind="cosine_shifted")
        np.testing.assert_allclose(np.diag(np.asarray(a_k)), 0.0, atol=0.0)

    def test_padding_region_not_leaked(self):
        """n far from the tile boundary: degrees must ignore padded cols."""
        x = row_normalize_features(jax.random.normal(jax.random.key(3), (129, 2)))
        _, d_k = ops.affinity_and_degree(x, kind="cosine_shifted")
        _, d_r = ref.affinity_and_degree_ref(x, kind="cosine_shifted")
        np.testing.assert_allclose(d_k, d_r, atol=1e-3, rtol=1e-5)


class TestPowerStepKernel:
    @pytest.mark.parametrize("n", [64, 129, 300, 512, 1000])
    def test_shape_sweep(self, n):
        key = jax.random.key(n)
        x = row_normalize_features(jax.random.normal(key, (n, 3)))
        a, d = ref.affinity_and_degree_ref(x, kind="cosine_shifted")
        v = jax.random.uniform(jax.random.key(n + 1), (n,))
        np.testing.assert_allclose(
            ops.degree_normalized_matvec(a, v, d),
            ref.degree_normalized_matvec_ref(a, v, d),
            atol=1e-5, rtol=1e-5,
        )

    @pytest.mark.parametrize("tm,tn", TILES)
    def test_tile_sweep(self, tm, tn):
        n = 400
        x = row_normalize_features(jax.random.normal(jax.random.key(9), (n, 3)))
        a, d = ref.affinity_and_degree_ref(x, kind="cosine_shifted")
        v = jax.random.uniform(jax.random.key(10), (n,))
        np.testing.assert_allclose(
            ops.degree_normalized_matvec(a, v, d, tm=tm, tn=tn),
            ref.degree_normalized_matvec_ref(a, v, d),
            atol=1e-5, rtol=1e-5,
        )

    def test_full_power_step_l1(self):
        n = 300
        x = row_normalize_features(jax.random.normal(jax.random.key(4), (n, 2)))
        a, d = ref.affinity_and_degree_ref(x, kind="cosine_shifted")
        v = jnp.ones((n,)) / n
        out = ops.power_step(a, v, d)
        np.testing.assert_allclose(jnp.sum(jnp.abs(out)), 1.0, atol=1e-5)
        np.testing.assert_allclose(out, ref.power_step_ref(a, v, d), atol=1e-6)

    def test_iterated_steps_match_reference_pic(self):
        """Running the kernel t times equals the reference power iteration."""
        n = 200
        x = row_normalize_features(jax.random.normal(jax.random.key(5), (n, 2)))
        a, d = ref.affinity_and_degree_ref(x, kind="cosine_shifted")
        v_k = v_r = d / jnp.sum(d)
        for _ in range(5):
            v_k = ops.power_step(a, v_k, d)
            v_r = ref.power_step_ref(a, v_r, d)
        np.testing.assert_allclose(v_k, v_r, atol=1e-6)


class TestKmeansAssignKernel:
    @pytest.mark.parametrize("n,d,k", [(100, 2, 3), (513, 5, 7), (1024, 1, 2),
                                       (2000, 8, 16), (333, 3, 130)])
    def test_shape_sweep(self, n, d, k):
        x = jax.random.normal(jax.random.key(n + d + k), (n, d))
        c = jax.random.normal(jax.random.key(n + d + k + 1), (k, d))
        l_k, d_k = ops.kmeans_assign(x, c)
        l_r, d_r = ref.kmeans_assign_ref(x, c)
        np.testing.assert_array_equal(l_k, l_r)
        np.testing.assert_allclose(d_k, d_r, atol=1e-4, rtol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        x = jax.random.normal(jax.random.key(6), (400, 3)).astype(dtype)
        c = jax.random.normal(jax.random.key(7), (5, 3)).astype(dtype)
        l_k, _ = ops.kmeans_assign(x, c)
        l_r, _ = ref.kmeans_assign_ref(x, c)
        match = float(jnp.mean((l_k == l_r).astype(jnp.float32)))
        assert match > 0.99  # bf16 ties may flip; near-total agreement required


class TestGramKernel:
    @pytest.mark.parametrize("n,r", [(64, 1), (100, 2), (300, 4), (517, 8),
                                     (1024, 3), (200, 16)])
    def test_shape_sweep(self, n, r):
        v = jax.random.uniform(jax.random.key(n + r), (n, r)) - 0.3
        g_k = ops.gram(v)
        g_r = ref.gram_ref(v)
        assert g_k.shape == (r, r) and g_k.dtype == jnp.float32
        np.testing.assert_allclose(g_k, g_r, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("tm", [128, 256, 512])
    def test_tile_sweep(self, tm):
        v = jax.random.normal(jax.random.key(0), (700, 4))
        np.testing.assert_allclose(ops.gram(v, tm=tm), ref.gram_ref(v),
                                   atol=1e-4, rtol=1e-5)

    def test_symmetric_and_psd_diag(self):
        v = jax.random.normal(jax.random.key(1), (333, 5))
        g = np.asarray(ops.gram(v))
        np.testing.assert_allclose(g, g.T, atol=1e-5)
        assert (np.diag(g) >= 0).all()

    def test_f32_accumulation_from_bf16_state(self):
        v = jax.random.uniform(jax.random.key(2), (400, 3))
        g16 = ops.gram(v.astype(jnp.bfloat16))
        assert g16.dtype == jnp.float32
        np.testing.assert_allclose(g16, ref.gram_ref(v), atol=2e-2, rtol=2e-2)

    def test_chunked_partials_sum_to_full(self):
        """The sharded contract: per-chunk Grams summed across chunks equal
        the full Gram (what op.sum(op.gram(v_loc)) computes under psum)."""
        v = jax.random.normal(jax.random.key(3), (512, 4))
        chunks = [ops.gram(v[i * 64:(i + 1) * 64]) for i in range(8)]
        np.testing.assert_allclose(sum(chunks), ref.gram_ref(v),
                                   atol=1e-4, rtol=1e-5)

    def test_registry_modes(self):
        assert set(ops.modes_for("gram")) == {"pallas", "reference"}


class TestKernelProperties:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(16, 384),
        m=st.integers(1, 9),
        kind=st.sampled_from(["cosine", "cosine_shifted", "rbf"]),
    )
    def test_affinity_property(self, n, m, kind):
        x = jax.random.normal(jax.random.key(n * 31 + m), (n, m))
        inp = x if kind == "rbf" else row_normalize_features(x)
        a_k, d_k = ops.affinity_and_degree(inp, kind=kind, sigma=1.1)
        a_r, d_r = ref.affinity_and_degree_ref(inp, kind=kind, sigma=1.1)
        np.testing.assert_allclose(a_k, a_r, atol=1e-5)
        np.testing.assert_allclose(d_k, d_r, atol=1e-3, rtol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(16, 384))
    def test_power_step_preserves_l1(self, n):
        x = row_normalize_features(jax.random.normal(jax.random.key(n), (n, 2)))
        a, d = ref.affinity_and_degree_ref(x, kind="cosine_shifted")
        v = jax.random.uniform(jax.random.key(n + 1), (n,))
        out = ops.power_step(a, v / jnp.sum(v), d)
        np.testing.assert_allclose(float(jnp.sum(jnp.abs(out))), 1.0, atol=1e-4)
