"""Shape/dtype/GQA sweeps for the Pallas flash-attention kernel vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(bh, bkv, s, d, dtype=jnp.float32, seed=0):
    q = (jax.random.normal(jax.random.key(seed), (bh, s, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.key(seed + 1), (bkv, s, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(jax.random.key(seed + 2), (bkv, s, d)) * 0.5).astype(dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("bh,bkv,s,d", [
        (4, 4, 128, 32),      # MHA
        (8, 2, 100, 16),      # GQA rep=4, ragged seq
        (6, 1, 256, 64),      # MQA
        (2, 2, 513, 32),      # seq not divisible by blocks
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_shape_sweep(self, bh, bkv, s, d, causal):
        q, k, v = _qkv(bh, bkv, s, d)
        o_k = ops.flash_attention(q, k, v, causal=causal,
                                  block_q=64, block_k=64)
        o_r = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   atol=2e-6, rtol=1e-5)

    @pytest.mark.parametrize("bq,bk", [(32, 64), (64, 32), (128, 128)])
    def test_block_sweep(self, bq, bk):
        q, k, v = _qkv(4, 2, 192, 32)
        o_k = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
        o_r = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   atol=2e-6, rtol=1e-5)

    def test_bf16(self):
        q, k, v = _qkv(4, 4, 128, 32, dtype=jnp.bfloat16)
        o_k = ops.flash_attention(q, k, v, block_q=64, block_k=64)
        o_r = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(o_k, np.float32),
                                   np.asarray(o_r, np.float32),
                                   atol=2e-2, rtol=2e-2)

    def test_matches_model_attention(self):
        """The kernel reproduces the jnp grouped attention used by the zoo."""
        from repro.configs import get_smoke_config
        from repro.models import layers as L
        cfg = get_smoke_config("h2o-danube-3-4b").replace(sliding_window=0)
        p = L.init_attention(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model)) * 0.5
        out_model, _ = L.attention(x, p, cfg, rope=False)

        b, s = 2, 64
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        q = (x @ p["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        k = (x @ p["wk"]).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
        v = (x @ p["wv"]).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
        o = ops.flash_attention(q.reshape(b * h, s, hd),
                                k.reshape(b * kv, s, hd),
                                v.reshape(b * kv, s, hd),
                                block_q=32, block_k=32)
        # kernel's bh layout is (b, h) major->minor with kv = bh//rep — match
        # by folding rep inside each batch's kv groups
        o = o.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, h * hd)
        out_kernel = o @ p["wo"]
        np.testing.assert_allclose(np.asarray(out_kernel),
                                   np.asarray(out_model), atol=5e-5,
                                   rtol=1e-4)
