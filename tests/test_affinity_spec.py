"""The pluggable affinity-graph subsystem (DESIGN.md §11).

Covers: AffinitySpec validation, the strided bandwidth-heuristic fix, the
row-top-k kernel vs its oracle (both statistics, stripes, ties), the
two-pass masked build (adaptive local scaling + kNN truncation) against
the dense jnp reference for BOTH the explicit and streaming kernels, the
bitwise explicit==streaming discipline under the new specs, matrix-free
spec rejection, and the subspace residual stopping rule (sweep reduction +
bitwise-pinned column 0).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AffinitySpec,
    GPICConfig,
    adjusted_rand_index,
    affinity_matrix,
    as_affinity_spec,
    gpic_matrix_free,
    knn_thresholds,
    local_scales,
    pic_reference,
    rbf_bandwidth_heuristic,
    run_gpic,
)
from repro.core.affinity import SCALE_FLOOR, matmat_matrix_free, row_normalize_features
from repro.core.graph import affinity_stats, scales_from_topk
from repro.data import gaussians, shuffle_points, three_circles
from repro.kernels import ops, ref
from repro.kernels.row_topk import row_topk_merge


def _points(n, m=3, seed=0):
    return jax.random.normal(jax.random.key(seed), (n, m))


class TestAffinitySpec:
    def test_defaults_are_dense_fixed(self):
        spec = AffinitySpec()
        assert spec.dense_fixed and not spec.adaptive and not spec.truncated
        assert spec.factorable

    def test_coercion(self):
        assert as_affinity_spec("rbf", sigma=0.4) == AffinitySpec(
            kind="rbf", sigma=0.4)
        spec = AffinitySpec(kind="rbf", knn_k=5)
        assert as_affinity_spec(spec, kind="cosine") is spec
        assert as_affinity_spec(None, kind="cosine") == AffinitySpec(
            kind="cosine")
        with pytest.raises(TypeError, match="AffinitySpec"):
            as_affinity_spec(42)

    @pytest.mark.parametrize("bad,match", [
        (dict(kind="warp"), "kind"),
        (dict(sigma=0.0), "sigma"),
        (dict(sigma=-2.0), "sigma"),
        (dict(bandwidth="auto"), "bandwidth"),
        (dict(kind="cosine_shifted", bandwidth="adaptive"), "rbf"),
        (dict(kind="rbf", bandwidth="adaptive", scale_k=0), "scale_k"),
        (dict(knn_k=0), "knn_k"),
    ])
    def test_constructor_rejections(self, bad, match):
        with pytest.raises(ValueError, match=match):
            AffinitySpec(**bad)

    def test_neighbor_rank_bounds_need_n(self):
        AffinitySpec(kind="rbf", knn_k=63).validate_for_n(64)
        with pytest.raises(ValueError, match="knn_k"):
            AffinitySpec(kind="rbf", knn_k=64).validate_for_n(64)
        with pytest.raises(ValueError, match="scale_k"):
            AffinitySpec(kind="rbf", bandwidth="adaptive",
                         scale_k=80).validate_for_n(64)

    def test_factorable_flags(self):
        assert not AffinitySpec(kind="rbf").factorable
        assert not AffinitySpec(knn_k=3).factorable
        assert AffinitySpec(kind="cosine").factorable


class TestFrontDoorValidation:
    """GPICConfig-level rejections (the PR 3 validation style)."""

    def _run(self, **cfg):
        x = jnp.asarray(_points(64, 2))
        return run_gpic(x, 2, GPICConfig(**cfg), key=jax.random.key(0))

    def test_matrix_free_rejects_truncation(self):
        with pytest.raises(ValueError, match="factorable"):
            self._run(engine="matrix_free", affinity=AffinitySpec(knn_k=5))

    def test_matrix_free_rejects_adaptive(self):
        with pytest.raises(ValueError, match="factorable"):
            self._run(engine="matrix_free", affinity=AffinitySpec(
                kind="rbf", bandwidth="adaptive"))

    def test_knn_k_bounds_at_n(self):
        with pytest.raises(ValueError, match=r"outside \[1, n\)"):
            self._run(affinity=AffinitySpec(kind="rbf", knn_k=64))

    def test_spec_and_legacy_shorthand_conflict(self):
        with pytest.raises(ValueError, match="not both"):
            self._run(affinity=AffinitySpec(kind="rbf", sigma=0.3),
                      affinity_kind="rbf", sigma=0.3)

    def test_fold_shift_rejects_truncation(self):
        # mesh-independent rejection: fold_shift needs a dense fixed spec
        with pytest.raises(ValueError, match="fold_shift"):
            self._run(affinity=AffinitySpec(knn_k=5), fold_shift=True)

    def test_residual_tol_needs_orthogonal(self):
        with pytest.raises(ValueError, match="residual_tol"):
            self._run(residual_tol=1e-3)
        with pytest.raises(ValueError, match="residual_tol"):
            self._run(embedding="orthogonal", n_vectors=2, residual_tol=-1.0)

    def test_residual_tol_needs_a_block(self):
        """r=1 orthogonal IS the classic loop — the rule could never arm,
        so silently ignoring it would fake early stopping. Rejected at the
        front door AND the engine."""
        from repro.core import batched_power_iteration
        with pytest.raises(ValueError, match="n_vectors"):
            self._run(embedding="orthogonal", n_vectors=1, residual_tol=1e-3)
        with pytest.raises(ValueError, match="never arm"):
            batched_power_iteration(lambda v: v, jnp.ones((8, 1)), 1e-5, 5,
                                    mode="orthogonal", residual_tol=1e-3)

    def test_direct_matrix_free_rejects_spec(self):
        x = jnp.asarray(_points(64, 2))
        with pytest.raises(ValueError, match="factorable"):
            gpic_matrix_free(x, 2, key=jax.random.key(0),
                             affinity=AffinitySpec(knn_k=5))
        with pytest.raises(ValueError, match="factorable"):
            matmat_matrix_free(row_normalize_features(x), jnp.ones((64, 1)),
                               AffinitySpec(kind="rbf"))


class TestBandwidthHeuristicSampling:
    def test_strided_sample_sees_every_cluster(self):
        """Regression (sampling bias): on cluster-SORTED data the first 512
        rows may all lie in one cluster, collapsing the median to the
        intra-cluster distance. The generators emit points class-by-class,
        so gaussians(2048) IS cluster-sorted: with 4 blobs of 512 the old
        leading slice saw exactly one blob. The strided sample must
        recover a bandwidth near the all-pairs median (inter-cluster
        scale), several times the intra-cluster one."""
        x, y = gaussians(2048, k=4, seed=0)
        assert (np.sort(y) == y).all()          # cluster-sorted, by design
        xj = jnp.asarray(x)
        sig = float(rbf_bandwidth_heuristic(xj))
        # ground truth from an unbiased random sample
        rng = np.random.default_rng(0)
        s = x[rng.choice(2048, 512, replace=False)]
        d = np.sqrt(np.maximum(
            np.sum(s * s, 1)[:, None] + np.sum(s * s, 1)[None, :]
            - 2 * s @ s.T, 0) + np.eye(512) * 1e9)
        sig_true = float(np.median(d))
        # the old leading-slice estimate: one blob's internal spread
        lead = x[:512]
        d0 = np.sqrt(np.maximum(
            np.sum(lead * lead, 1)[:, None] + np.sum(lead * lead, 1)[None, :]
            - 2 * lead @ lead.T, 0) + np.eye(512) * 1e9)
        sig_lead = float(np.median(d0))
        assert sig_lead < 0.25 * sig_true       # the bias being fixed
        assert abs(sig - sig_true) < 0.25 * sig_true

    @pytest.mark.parametrize("n", [1000, 1500])
    def test_ceil_stride_covers_tail_sizes(self, n):
        """Regression (stride rounding): floor division degenerates to the
        leading slice for sample < n < 2*sample (n=1000 → stride 1) and
        drops the tail class when n/sample is non-integral (n=1500 →
        floor-stride 2 never samples rows past 1022). The ceil stride
        must keep the estimate near the unbiased median at these sizes."""
        x, y = gaussians(n, k=4, seed=0)
        sig = float(rbf_bandwidth_heuristic(jnp.asarray(x)))
        rng = np.random.default_rng(0)
        s = x[rng.choice(n, 512, replace=False)]
        d = np.sqrt(np.maximum(
            np.sum(s * s, 1)[:, None] + np.sum(s * s, 1)[None, :]
            - 2 * s @ s.T, 0) + np.eye(512) * 1e9)
        sig_true = float(np.median(d))
        assert abs(sig - sig_true) < 0.25 * sig_true

    def test_order_robust(self):
        """The strided estimate on cluster-sorted input must agree with
        the estimate on the SAME data shuffled — the property the old
        leading slice violated by construction."""
        x, y = gaussians(2048, k=4, seed=1)
        xs, _ = shuffle_points(x, y, seed=3)
        a = float(rbf_bandwidth_heuristic(jnp.asarray(x)))
        b = float(rbf_bandwidth_heuristic(jnp.asarray(xs)))
        assert abs(a - b) < 0.2 * max(a, b)

    def test_small_n_unchanged(self):
        """n <= sample keeps the full-population median (stride 1)."""
        x = jnp.asarray(_points(100, 2))
        assert float(rbf_bandwidth_heuristic(x)) > 0


class TestRowTopkKernel:
    @pytest.mark.parametrize("n,m", [(64, 2), (129, 3), (300, 5), (517, 2)])
    @pytest.mark.parametrize("stat,kind", [("neg_sqdist", "rbf"),
                                           ("similarity", "rbf"),
                                           ("similarity", "cosine_shifted"),
                                           ("similarity", "cosine")])
    def test_shape_sweep(self, n, m, stat, kind):
        x = _points(n, m, seed=n + m)
        inp = x if kind == "rbf" else row_normalize_features(x)
        tk = ops.row_topk(inp, k=7, stat=stat, kind=kind, sigma=0.8)
        tr = ref.row_topk_ref(inp, k=7, stat=stat, kind=kind, sigma=0.8)
        assert tk.shape == (n, 7)
        np.testing.assert_allclose(tk, tr, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("k", [1, 2, 16, 63])
    def test_k_sweep_descending(self, k):
        x = _points(200, 3, seed=k)
        tk = np.asarray(ops.row_topk(x, k=k, stat="neg_sqdist", kind="rbf"))
        assert (np.diff(tk, axis=1) <= 0).all()  # descending rows
        np.testing.assert_allclose(
            tk, ref.row_topk_ref(x, k=k, stat="neg_sqdist", kind="rbf"),
            atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("tm,tn", [(128, 128), (128, 256), (256, 128)])
    def test_tile_sweep(self, tm, tn):
        x = _points(300, 4, seed=1)
        np.testing.assert_allclose(
            ops.row_topk(x, k=5, stat="neg_sqdist", kind="rbf", tm=tm, tn=tn),
            ref.row_topk_ref(x, k=5, stat="neg_sqdist", kind="rbf"),
            atol=1e-5, rtol=1e-5)

    def test_stripe_offsets_mask_global_diagonal(self):
        """The ring contract: per-stage stripes with offsets, merged, equal
        the square self-pass — and k > block width pads with -inf."""
        x = _points(256, 3, seed=2)
        k = 40
        full = np.asarray(ops.row_topk(x, k=k, stat="neg_sqdist", kind="rbf"))
        rows = x[:64]
        buf = jnp.full((64, k), -jnp.inf)
        for s in range(4):
            part = ops.row_topk(rows, x[s * 64:(s + 1) * 64], k=k,
                                stat="neg_sqdist", kind="rbf",
                                row_offset=0, col_offset=s * 64)
            buf = row_topk_merge(buf, part, k)
        np.testing.assert_allclose(np.asarray(buf), full[:64],
                                   atol=1e-5, rtol=1e-5)

    def test_ties_consumed_once(self):
        """Duplicate points create exactly-tied scores; each occurrence
        must be counted once (index tie-break, not suppress-all)."""
        base = np.asarray(_points(8, 2, seed=3))
        x = jnp.asarray(np.concatenate([base, base, base], axis=0))  # 24 pts
        tk = np.asarray(ops.row_topk(x, k=3, stat="neg_sqdist", kind="rbf"))
        # every point has exactly 2 duplicates: top-2 neg-sq-dists are 0,
        # the 3rd is strictly negative
        np.testing.assert_allclose(tk[:, :2], 0.0, atol=1e-6)
        assert (tk[:, 2] < -1e-6).all()

    def test_adaptive_scaled_similarity(self):
        x = _points(150, 3, seed=4)
        scl = local_scales(x, 7)
        tk = ops.row_topk(x, k=9, stat="similarity", kind="rbf",
                          scale_r=scl, scale_c=scl)
        tr = ref.row_topk_ref(x, k=9, stat="similarity", kind="rbf",
                              scale_r=scl, scale_c=scl)
        np.testing.assert_allclose(tk, tr, atol=1e-5, rtol=1e-5)

    def test_registry_modes(self):
        assert set(ops.modes_for("row_topk")) == {"pallas", "reference"}


class TestTwoPassMaskedBuild:
    """Pass 1 (row_topk) + pass 2 (masked affinity kernels) against the
    dense jnp reference (affinity_matrix(spec=...))."""

    SPECS = [
        AffinitySpec(kind="rbf", sigma=0.5, knn_k=10),
        AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=7),
        AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=5, knn_k=12),
        AffinitySpec(kind="cosine_shifted", knn_k=15),
        AffinitySpec(kind="cosine", knn_k=8),
    ]

    @pytest.mark.parametrize("spec", SPECS, ids=str)
    @pytest.mark.parametrize("n", [128, 300])
    def test_explicit_build_matches_dense_reference(self, spec, n):
        x = _points(n, 3, seed=n)
        inp = x if spec.kind == "rbf" else row_normalize_features(x)
        scale, thr = affinity_stats(inp, spec)
        a_k, d_k = ops.affinity_and_degree(inp, spec=spec, scale_r=scale,
                                           scale_c=scale, thr=thr)
        a_ref = affinity_matrix(inp, spec=spec)
        np.testing.assert_allclose(a_k, a_ref, atol=1e-5)
        np.testing.assert_allclose(d_k, jnp.sum(a_ref, axis=1),
                                   atol=1e-3, rtol=1e-5)

    @pytest.mark.parametrize("spec", SPECS, ids=str)
    def test_streaming_bitwise_equals_explicit(self, spec):
        """The §5 discipline extends to every spec: streamed degrees and
        sweeps equal the explicit masked build bitwise at matching tiles."""
        x = _points(300, 4, seed=9)
        inp = x if spec.kind == "rbf" else row_normalize_features(x)
        scale, thr = affinity_stats(inp, spec, tile=128)
        kw = dict(spec=spec, scale_r=scale, scale_c=scale, thr=thr,
                  tm=128, tn=128)
        a_k, d_e = ops.affinity_and_degree(inp, **kw)
        d_s = ops.streaming_degree(inp, **kw)
        np.testing.assert_array_equal(d_s, d_e)
        v = jax.random.uniform(jax.random.key(1), (300, 3))
        u_s = ops.streaming_matmat(inp, v, d_e, **kw)
        u_e = ops.degree_normalized_matmat(a_k, v, d_e, tm=128, tn=128)
        np.testing.assert_allclose(u_s, u_e, atol=1e-6)

    def test_truncated_rows_keep_knn_k_entries(self):
        """Each row keeps >= knn_k entries (ties may keep more), every
        kept entry >= the row's threshold, and the diagonal stays zero."""
        x = _points(200, 2, seed=5)
        spec = AffinitySpec(kind="rbf", sigma=0.5, knn_k=10)
        a = np.asarray(affinity_matrix(x, spec=spec))
        nnz = (a > 0).sum(axis=1)
        assert (nnz >= 10).all()
        assert (nnz <= 12).all()                 # no wholesale densification
        np.testing.assert_allclose(np.diag(a), 0.0, atol=0.0)

    def test_dense_spec_is_bitwise_the_legacy_build(self):
        """The bitwise-pinned baseline: the dense fixed spec and the legacy
        kind/sigma route compile to identical results."""
        x = _points(300, 3, seed=6)
        a0, d0 = ops.affinity_and_degree(x, kind="rbf", sigma=0.5)
        a1, d1 = ops.affinity_and_degree(
            x, spec=AffinitySpec(kind="rbf", sigma=0.5))
        np.testing.assert_array_equal(a0, a1)
        np.testing.assert_array_equal(d0, d1)

    def test_local_scales_floor_on_duplicates(self):
        base = np.asarray(_points(16, 2, seed=7))
        x = jnp.asarray(np.concatenate([base] * 8, axis=0))   # 8 copies
        scl = np.asarray(local_scales(x, 3))   # 3rd NN of any point: itself
        np.testing.assert_allclose(scl, SCALE_FLOOR, atol=0.0)

    def test_scales_from_topk_matches_dense_oracle(self):
        x = _points(200, 3, seed=8)
        nk = ops.row_topk(x, k=7, stat="neg_sqdist", kind="rbf")
        np.testing.assert_allclose(scales_from_topk(nk), local_scales(x, 7),
                                   atol=1e-5, rtol=1e-5)

    def test_knn_thresholds_oracle(self):
        x = _points(150, 2, seed=10)
        a = affinity_matrix(x, "rbf", sigma=0.5)
        thr = np.asarray(knn_thresholds(a, 5))
        a_np = np.where(np.eye(150, dtype=bool), -np.inf, np.asarray(a))
        expect = np.sort(a_np, axis=1)[:, -5]
        np.testing.assert_allclose(thr, expect, atol=1e-6)


class TestSpecPipeline:
    """End-to-end run_gpic under the new specs (single device)."""

    def test_engines_agree_on_knn_spec(self):
        x, _ = three_circles(400, seed=0)
        cfg = GPICConfig(affinity=AffinitySpec(kind="rbf", sigma=0.3,
                                               knn_k=30),
                         max_iter=300)
        r_e = run_gpic(jnp.asarray(x), 3, cfg, key=jax.random.key(1))
        r_s = run_gpic(jnp.asarray(x), 3, cfg.with_(engine="streaming"),
                       key=jax.random.key(1))
        np.testing.assert_array_equal(np.asarray(r_e.labels),
                                      np.asarray(r_s.labels))
        np.testing.assert_array_equal(np.asarray(r_e.embedding),
                                      np.asarray(r_s.embedding))

    def test_pic_reference_oracle_matches_gpic_on_spec(self):
        """The dense jnp oracle and the two-pass Pallas build agree on the
        full pipeline (labels + iteration count) for an adaptive+kNN spec."""
        x, _ = gaussians(256, seed=1)
        spec = AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=7,
                            knn_k=12)
        ref_res = pic_reference(jnp.asarray(x), 4, key=jax.random.key(2),
                                affinity=spec, max_iter=200)
        acc = run_gpic(jnp.asarray(x), 4, GPICConfig(affinity=spec,
                                                     max_iter=200),
                       key=jax.random.key(2))
        assert int(ref_res.n_iter) == int(acc.n_iter)
        np.testing.assert_allclose(ref_res.embedding, acc.embedding,
                                   atol=1e-6, rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(ref_res.labels),
                                      np.asarray(acc.labels))


class TestSubspaceResidualStopping:
    """The ROADMAP open item: orthogonal-mode block columns stop on the
    ||WV − VΛ|| residual instead of running to max_iter."""

    def _cfg(self, **kw):
        return GPICConfig(affinity_kind="rbf", sigma=0.3, max_iter=400,
                          n_vectors=2, embedding="orthogonal", **kw)

    def test_sweep_count_reduction_and_pinned_column0(self):
        x, y = three_circles(480, seed=0)
        xj = jnp.asarray(x)
        full = run_gpic(xj, 3, self._cfg(), key=jax.random.key(1))
        res = run_gpic(xj, 3, self._cfg(residual_tol=1e-3),
                       key=jax.random.key(1))
        # the block column ran to max_iter without the rule; with it the
        # loop stops at subspace convergence
        assert int(full.n_iter_cols[1]) == 400
        assert int(res.n_iter_cols[1]) < 200
        assert bool(res.converged_cols.all())
        # column 0 (the paper's trajectory) is untouched: same count AND
        # bitwise-identical embedding
        assert int(res.n_iter_cols[0]) == int(full.n_iter_cols[0])
        np.testing.assert_array_equal(np.asarray(res.embedding),
                                      np.asarray(full.embedding))

    def test_quality_preserved(self):
        x, y = three_circles(480, seed=0)
        res = run_gpic(jnp.asarray(x), 3, self._cfg(residual_tol=1e-3),
                       key=jax.random.key(1))
        assert adjusted_rand_index(y, np.asarray(res.labels)) >= 0.9

    def test_default_off_is_bitwise_pr3(self):
        """residual_tol=None compiles the exact prior loop: same per-column
        counts and bitwise state as a run that never heard of the rule."""
        x, _ = gaussians(256, seed=0)
        cfg = GPICConfig(affinity_kind="rbf", sigma=0.3, max_iter=100,
                         n_vectors=2, embedding="orthogonal")
        a = run_gpic(jnp.asarray(x), 3, cfg, key=jax.random.key(1))
        b = run_gpic(jnp.asarray(x), 3, cfg.with_(residual_tol=None),
                     key=jax.random.key(1))
        np.testing.assert_array_equal(np.asarray(a.embeddings),
                                      np.asarray(b.embeddings))
        np.testing.assert_array_equal(np.asarray(a.n_iter_cols),
                                      np.asarray(b.n_iter_cols))
