"""Tests for the synthetic dataset generators + balanced subsampling."""
import numpy as np
import pytest

from repro.data import dataset_by_name
from repro.data.synthetic import subsample_balanced

ALL = ["two_moons", "three_circles", "cassini", "gaussians", "shapes", "smiley"]


@pytest.mark.parametrize("name", ALL)
def test_shapes_and_balance(name):
    x, y, k = dataset_by_name(name, 999, seed=0)
    assert x.shape == (999, 2)
    assert x.dtype == np.float32
    assert y.shape == (999,)
    assert set(np.unique(y)) == set(range(k))
    counts = np.bincount(y)
    assert counts.max() - counts.min() <= k  # near-balanced
    assert np.isfinite(x).all()


@pytest.mark.parametrize("name", ALL)
def test_deterministic_given_seed(name):
    x1, y1, _ = dataset_by_name(name, 256, seed=7)
    x2, y2, _ = dataset_by_name(name, 256, seed=7)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _, _ = dataset_by_name(name, 256, seed=8)
    assert not np.array_equal(x1, x3)


def test_subsample_balanced_fraction():
    x, y, k = dataset_by_name("gaussians", 4000, seed=0)
    xs, ys = subsample_balanced(x, y, 0.1, seed=1)
    assert abs(len(ys) - 400) <= k
    counts = np.bincount(ys, minlength=k)
    assert counts.max() - counts.min() <= 1


def test_subsample_tiny_fraction_keeps_all_classes():
    x, y, k = dataset_by_name("smiley", 45000, seed=0)
    xs, ys = subsample_balanced(x, y, 0.001, seed=2)  # 45 points
    assert set(np.unique(ys)) == set(range(k))
