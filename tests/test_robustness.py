"""The fault-injection suite behind the PR-6 robustness contract.

Every GPIC entry point either succeeds with a diagnosable result
(``PICResult.health`` populated) or fails with a typed ``GPICError``
subclass — never silent garbage (DESIGN.md §12). One test class per fault
class of the matrix:

  non-finite features    front door: NonFiniteInputError / sanitize note
  degenerate shapes      front door: InvalidInputError (n < k, empty,
                         constant rows)
  zero-degree rows       exact-zero sweep output (a zero-degree row's u
                         row is already exactly 0 under the floored
                         divide), isolated_rows count off the degree
                         vector, DegenerateGraphError when every row is
                         isolated
  disconnected graphs    on-device component probe on truncated specs
  dead/stalled columns   COL_* latches in the one convergence loop
  kernel failures        per-op reference fallback + health note, plus
                         the retry_on_fallback re-run contract (PR 8)
  directed probe bias    symmetrized reachability regression: asymmetric
                         kNN edges must not split weakly-attached rows
                         into phantom components (PR 8)
  truncated residuals    subspace_residual and op.degree under kNN
                         truncation (post-mask degrees, PR 8)
  corrupted ring stage   sharded streaming fault hook (mesh subprocess)

The mesh tests run in a subprocess with 8 host devices (same harness as
test_pipeline_parity) and assert local and sharded runs report identical
health diagnostics.
"""
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_in_mesh_subprocess
from repro.core import (
    AffinitySpec,
    COL_MAXITER,
    COL_NONFINITE,
    COL_OK,
    COL_STALLED,
    COL_ZERO,
    DegenerateGraphError,
    GPICConfig,
    GPICError,
    HealthReport,
    InvalidInputError,
    NonFiniteInputError,
    PowerDivergenceError,
    as_operator,
    batched_power_iteration,
    count_bad_rows,
    degree_guard,
    describe_status,
    kmeans,
    run_gpic,
    subspace_residual,
)
from repro.core.health import raise_for_health
from repro.data.synthetic import gaussians
from repro.kernels import ops
from repro.train.fault_tolerance import (
    ClusteringFaultHarness,
    inject_nan_features,
)


def _blobs(n=64, k=3, seed=0):
    return gaussians(n, k=k, seed=seed)[0]


# ---------------------------------------------------------------------------
# Front-door validation (typed errors before any device work)
# ---------------------------------------------------------------------------


class TestFrontDoor:
    def test_nan_features_raise_typed(self):
        x = inject_nan_features(_blobs(), [3, 7])
        with pytest.raises(NonFiniteInputError, match="non-finite"):
            run_gpic(x, 3)

    def test_nonfinite_error_is_invalid_input_and_value_error(self):
        # the hierarchy contract: callers may catch the base classes
        assert issubclass(NonFiniteInputError, InvalidInputError)
        assert issubclass(InvalidInputError, ValueError)
        assert issubclass(InvalidInputError, GPICError)
        assert issubclass(DegenerateGraphError, GPICError)
        assert issubclass(PowerDivergenceError, GPICError)

    def test_sanitize_recovers_and_records(self):
        x = inject_nan_features(_blobs(), [3, 7])
        res = run_gpic(x, 3, GPICConfig(sanitize=True))
        assert any(n.startswith("sanitized:") for n in res.health.notes)
        labels = np.asarray(res.labels)
        assert np.isfinite(np.asarray(res.embedding)).all()
        assert len(np.unique(labels)) == 3

    def test_inf_features_raise_typed(self):
        x = inject_nan_features(_blobs(), [0], value=float("inf"))
        with pytest.raises(NonFiniteInputError):
            run_gpic(x, 3)

    def test_n_less_than_k(self):
        with pytest.raises(InvalidInputError, match="k=8"):
            run_gpic(_blobs()[:5], 8)

    def test_empty_matrix(self):
        with pytest.raises(InvalidInputError, match="empty"):
            run_gpic(np.zeros((0, 4), np.float32), 2)

    def test_bad_ndim(self):
        with pytest.raises(InvalidInputError, match="matrix"):
            run_gpic(np.zeros((16,), np.float32), 2)

    def test_constant_rows(self):
        x = np.ones((32, 4), np.float32)
        with pytest.raises(InvalidInputError, match="identical"):
            run_gpic(x, 2)


# ---------------------------------------------------------------------------
# Zero-degree rows / degenerate graphs
# ---------------------------------------------------------------------------


class TestZeroDegree:
    def test_degree_guard_masks_isolated_rows(self):
        u = jnp.asarray(np.random.RandomState(0).randn(6, 2), jnp.float32)
        d = jnp.asarray([1.0, 0.0, 2.5, jnp.nan, 1e-25, jnp.inf])
        out = degree_guard(u, d)
        # healthy rows divide bitwise as the old 1e-30-floor guard did
        assert bool(jnp.all(out[0] == u[0] / 1.0))
        assert bool(jnp.all(out[2] == u[2] / 2.5))
        assert bool(jnp.all(out[4] == u[4] / 1e-25))
        # zero and NaN degrees mask to exact zero (NaN > 0 is False)
        assert bool(jnp.all(out[1] == 0.0))
        assert bool(jnp.all(out[3] == 0.0))
        # inf degree is "> 0": divides to 0 the normal way
        assert np.isfinite(np.asarray(out)).all()
        # 1-D u works too
        assert bool(jnp.all(degree_guard(u[:, 0], d)[1] == 0.0))

    def test_count_bad_rows(self):
        d = jnp.asarray([1.0, 0.0, jnp.nan, 3.0])
        assert int(count_bad_rows(d)) == 2
        assert int(count_bad_rows(jnp.ones(5))) == 0

    def test_rbf_underflow_outlier_is_isolated_not_nan(self):
        # the outlier's similarities all underflow to exact 0 under a small
        # sigma -> a zero-degree row; its sweep output is already exactly
        # zero (all-zero A row => u row 0) and the health report counts
        # it — no NaN anywhere
        rs = np.random.RandomState(1)
        x = np.concatenate([rs.randn(40, 2).astype(np.float32) * 0.2,
                            np.full((1, 2), 60.0, np.float32)])
        res = run_gpic(x, 2, GPICConfig(affinity_kind="rbf", sigma=0.5))
        assert int(res.health.isolated_rows) == 1
        assert np.isfinite(np.asarray(res.embeddings)).all()
        assert np.isfinite(np.asarray(res.embedding)).all()

    def test_all_rows_isolated_raises_degenerate(self):
        rs = np.random.RandomState(2)
        x = (rs.randn(24, 3) * 1e4).astype(np.float32)
        with pytest.raises(DegenerateGraphError, match="isolated"):
            run_gpic(x, 3, GPICConfig(affinity_kind="rbf", sigma=1e-3))

    def test_huge_finite_features_raise_typed(self):
        # 1e38 is finite so the front door admits it, but the rbf distances
        # overflow: every degree goes non-finite -> counted isolated ->
        # typed error, not NaN labels
        rs = np.random.RandomState(3)
        x = (np.sign(rs.randn(32, 4)) * 1e38).astype(np.float32)
        with pytest.raises(GPICError):
            run_gpic(x, 3, GPICConfig(affinity_kind="rbf", sigma=1.0))


# ---------------------------------------------------------------------------
# Disconnected components (truncated kNN graphs)
# ---------------------------------------------------------------------------


class TestComponentProbe:
    def test_two_blobs_knn_reports_two_components(self):
        rs = np.random.RandomState(0)
        x = np.concatenate([
            rs.randn(32, 2).astype(np.float32) * 0.1,
            rs.randn(32, 2).astype(np.float32) * 0.1 + 50.0,
        ])
        spec = AffinitySpec(kind="rbf", sigma=0.5, knn_k=8)
        res = run_gpic(x, 2, GPICConfig(affinity=spec))
        assert int(res.health.n_components) == 2
        comp = np.asarray(res.health.components)
        # ids are by discovery order: rows 0..31 -> 0, rows 32.. -> 1
        assert (comp[:32] == 0).all() and (comp[32:] == 1).all()

    def test_connected_graph_reports_one(self):
        rs = np.random.RandomState(0)
        x = rs.randn(64, 2).astype(np.float32) * 0.5   # one dense cloud
        spec = AffinitySpec(kind="rbf", sigma=1.0, knn_k=16)
        res = run_gpic(x, 3, GPICConfig(affinity=spec))
        assert int(res.health.n_components) == 1

    def test_probe_components_agree_with_clustering(self):
        # three well-separated blobs under kNN truncation disconnect into
        # exactly their blobs: the probe's component ids ARE the labels
        x = _blobs(64, k=3)
        spec = AffinitySpec(kind="rbf", sigma=1.0, knn_k=16)
        res = run_gpic(x, 3, GPICConfig(affinity=spec))
        assert int(res.health.n_components) == 3
        assert (np.asarray(res.health.components)
                == np.asarray(res.labels)).all()

    def test_dense_spec_skips_probe(self):
        res = run_gpic(_blobs(), 3)
        assert int(res.health.n_components) == -1
        assert (np.asarray(res.health.components) == -1).all()

    def test_component_probe_opt_out(self):
        spec = AffinitySpec(kind="rbf", sigma=1.0, knn_k=16)
        res = run_gpic(_blobs(64, k=3), 3,
                       GPICConfig(affinity=spec, component_probe=False))
        assert int(res.health.n_components) == -1


# ---------------------------------------------------------------------------
# Divergence latches in the one convergence loop
# ---------------------------------------------------------------------------


class TestColumnLatches:
    def test_zero_v0_column_latches_col_zero(self):
        # an all-zero start column was previously a hidden 0/0: frozen by
        # the 1e-30 floor and reported as a normal converged column
        op = lambda v: v * 0.5
        v0 = jnp.stack([jnp.ones(8), jnp.zeros(8)], axis=1)
        v, t_cols, done, status = batched_power_iteration(
            op, v0, 1e-9, 30, return_status=True)
        assert int(status[1]) & COL_ZERO
        assert bool(done[1])
        assert bool(jnp.all(v[:, 1] == 0.0))
        assert int(status[0]) == COL_OK

    def test_nonfinite_column_latched_and_quarantined(self):
        # a sweep that injects NaN into column 0 only: the column is zeroed
        # and latched; the healthy column converges normally
        def op(v):
            u = v * 0.5
            return u.at[0, 0].set(jnp.nan)
        v0 = jnp.ones((8, 2))
        v, t_cols, done, status = batched_power_iteration(
            op, v0, 1e-9, 30, return_status=True)
        assert int(status[0]) & COL_NONFINITE
        assert bool(jnp.all(v[:, 0] == 0.0))
        assert np.isfinite(np.asarray(v)).all()
        assert int(status[1]) == COL_OK

    def test_periodic_trajectory_flags_stall(self):
        # a 120-degree rotation repeats its deltas with period 3, so the
        # acceleration statistic is a positive constant: never converges,
        # never improves -> COL_STALLED + COL_MAXITER
        c, s = np.cos(2 * np.pi / 3), np.sin(2 * np.pi / 3)
        rot = jnp.asarray(np.array([[c, -s], [s, c]], np.float32))
        v0 = jnp.asarray(np.array([[1.0], [0.0]], np.float32))
        _v, _t, done, status = batched_power_iteration(
            lambda v: rot @ v, v0, 1e-7, 40, return_status=True)
        assert not bool(done[0])
        assert int(status[0]) == (COL_STALLED | COL_MAXITER)

    def test_converging_run_never_stalls(self):
        op = lambda v: v * jnp.asarray([0.9, 0.5])[None, :]
        v0 = jnp.ones((8, 2))
        _v, _t, done, status = batched_power_iteration(
            op, v0, 1e-9, 200, return_status=True)
        assert bool(jnp.all(done))
        assert (np.asarray(status) == COL_OK).all()

    def test_describe_status(self):
        assert describe_status(COL_OK) == ("ok",)
        assert describe_status(COL_STALLED | COL_MAXITER) == (
            "maxiter", "stalled")
        assert describe_status(COL_ZERO) == ("zero",)

    def test_collect_health_false_is_bitwise_neutral(self):
        # the latches are pure observers: compiling them out changes nothing
        op = lambda v: v * jnp.asarray([0.9, 0.7])[None, :]
        v0 = jnp.ones((16, 2)) / 16.0
        va, ta, da = batched_power_iteration(op, v0, 1e-9, 60,
                                             collect_health=True)
        vb, tb, db = batched_power_iteration(op, v0, 1e-9, 60,
                                             collect_health=False)
        assert bool(jnp.all(va == vb))
        assert bool(jnp.all(ta == tb)) and bool(jnp.all(da == db))

    def test_subspace_residual_zero_block_reports_inf(self):
        # a dead (all-zero) sweep output is 0/0 — previously a false
        # "converged" 0.0; the guard reports inf so the residual rule can
        # never stop on a dead block
        v = jnp.ones((8, 2))
        u = jnp.zeros((8, 2))
        assert bool(jnp.isinf(subspace_residual(as_operator(lambda x: x),
                                                v, u)))

    def test_raise_for_health_all_columns_dead(self):
        h = HealthReport(
            col_status=jnp.asarray([COL_ZERO, COL_NONFINITE], jnp.int32),
            isolated_rows=jnp.int32(1),
            n_components=jnp.int32(-1),
            components=jnp.full((8,), -1, jnp.int32))
        with pytest.raises(PowerDivergenceError, match="dead"):
            raise_for_health(h, 8)
        # partial damage returns normally
        h_ok = HealthReport(
            col_status=jnp.asarray([COL_OK, COL_ZERO], jnp.int32),
            isolated_rows=jnp.int32(1),
            n_components=jnp.int32(-1),
            components=jnp.full((8,), -1, jnp.int32))
        raise_for_health(h_ok, 8)


# ---------------------------------------------------------------------------
# Kernel-failure graceful degradation
# ---------------------------------------------------------------------------


class TestKernelFallback:
    def _clean(self):
        ops.reset_kernel_fallbacks()
        jax.clear_caches()

    def test_forced_failure_falls_back_and_reports(self):
        self._clean()
        try:
            with ops.forced_kernel_failure("gram"):
                res = run_gpic(_blobs(), 3,
                               GPICConfig(embedding="orthogonal",
                                          n_vectors=2))
            assert "kernel_fallback:gram" in res.health.notes
            assert "gram" in ops.kernel_fallbacks()
            assert len(np.unique(np.asarray(res.labels))) == 3
        finally:
            self._clean()

    def test_fallback_is_sticky_then_resettable(self):
        self._clean()
        try:
            with ops.forced_kernel_failure("power_step"):
                ops.power_step(jnp.eye(8), jnp.ones(8), jnp.ones(8))
            assert "power_step" in ops.kernel_fallbacks()
            # sticky: serves the oracle without re-raising after the cm exits
            ops.power_step(jnp.eye(8), jnp.ones(8), jnp.ones(8))
            assert list(ops.kernel_fallbacks()) == ["power_step"]
        finally:
            self._clean()
        assert ops.kernel_fallbacks() == {}

    def test_fallback_result_matches_oracle(self):
        self._clean()
        try:
            a = jnp.asarray(np.random.RandomState(0).rand(32, 32),
                            jnp.float32)
            v = jnp.ones((32, 2))
            d = jnp.sum(a, axis=1)
            with ops.forced_kernel_failure("degree_normalized_matmat"):
                got = ops.degree_normalized_matmat(a, v, d)
            want = ops.degree_normalized_matmat(a, v, d,
                                                force_reference=True)
            assert bool(jnp.all(got == want))
        finally:
            self._clean()


# ---------------------------------------------------------------------------
# Symmetrized component probe (PR-8 bugfix): a kNN graph is DIRECTED —
# row i keeping j among its top-k does not mean j keeps i. The probe's
# reachability must expand through A and A^T; following A alone splits
# weakly-attached rows into phantom components.
# ---------------------------------------------------------------------------


class TestSymmetrizedComponentProbe:
    def _asymmetric_two_blobs(self):
        # an outlier at (2.5, 0) picks blob-a points as ITS 3 neighbours,
        # but no blob-a point keeps the outlier: every A-edge touching the
        # outlier is one-directional, and the directed expansion that
        # seeds on it reaches rows whose own rows never link back
        rs = np.random.RandomState(0)
        blob_a = rs.randn(31, 2).astype(np.float32) * 0.3
        blob_b = rs.randn(32, 2).astype(np.float32) * 0.3 + 50.0
        x = np.concatenate(
            [np.array([[2.5, 0.0]], np.float32), blob_a, blob_b])
        return x, AffinitySpec(kind="rbf", sigma=1.0, knn_k=3)

    def test_directed_probe_overcounts_symmetrized_is_exact(self):
        import dataclasses

        from repro.core.health import graph_component_probe
        from repro.core.operators import explicit_operator

        x, spec = self._asymmetric_two_blobs()
        op = explicit_operator(jnp.asarray(x), spec=spec, tile=32)
        # truncated operators bind matmat_t; stripping it reproduces the
        # pre-fix directed expansion
        directed = dataclasses.replace(op, matmat_t=None)
        n_directed, _ = graph_component_probe(directed, x.shape[0])
        n_sym, comp = graph_component_probe(op, x.shape[0])
        assert int(n_directed) == 7     # phantom components
        assert int(n_sym) == 2          # the two blobs
        comp = np.asarray(comp)
        assert (comp[:32] == comp[0]).all()
        assert (comp[32:] == comp[32]).all()
        assert comp[0] != comp[32]

    def test_end_to_end_probe_is_symmetrized(self):
        x, spec = self._asymmetric_two_blobs()
        res = run_gpic(x, 2, GPICConfig(affinity=spec, tile=32))
        assert int(res.health.n_components) == 2


# ---------------------------------------------------------------------------
# retry_on_fallback (PR-8 bugfix): a mid-run kernel fallback leaves a
# MIXED kernel/reference trajectory; opting in re-runs the whole pipeline
# on the reference oracles and upgrades the note
# ---------------------------------------------------------------------------


class TestRetryOnFallback:
    def _clean(self):
        ops.reset_kernel_fallbacks()
        jax.clear_caches()

    def _cfg(self, **kw):
        return GPICConfig(embedding="orthogonal", n_vectors=2, **kw)

    def test_retry_upgrades_note_and_matches_reference(self):
        self._clean()
        try:
            with ops.forced_kernel_failure("gram"):
                res = run_gpic(_blobs(), 3,
                               self._cfg(retry_on_fallback=True))
            assert "kernel_fallback_retried:gram" in res.health.notes
            assert "kernel_fallback:gram" not in res.health.notes
            self._clean()
            want = run_gpic(_blobs(), 3, self._cfg(use_pallas=False))
            # the retried result IS the all-reference run, bitwise
            np.testing.assert_array_equal(np.asarray(res.labels),
                                          np.asarray(want.labels))
            np.testing.assert_array_equal(np.asarray(res.embeddings),
                                          np.asarray(want.embeddings))
        finally:
            self._clean()

    def test_default_keeps_mixed_trajectory_note(self):
        self._clean()
        try:
            with ops.forced_kernel_failure("gram"):
                res = run_gpic(_blobs(), 3, self._cfg())
            assert "kernel_fallback:gram" in res.health.notes
            assert not any("retried" in n for n in res.health.notes)
        finally:
            self._clean()


# ---------------------------------------------------------------------------
# subspace_residual under truncation (PR-8 bugfix): the residual's W must
# be the POST-MASK operator — degrees from the surviving entries only —
# so the residual_tol rule composes with knn_k specs
# ---------------------------------------------------------------------------


class TestResidualUnderTruncation:
    def test_truncated_operator_degrees_are_post_mask(self):
        from repro.core.operators import explicit_operator

        x = _blobs(96, k=3)
        sigma, kk = 0.5, 8
        op = explicit_operator(
            jnp.asarray(x), spec=AffinitySpec(kind="rbf", sigma=sigma,
                                              knn_k=kk), tile=32)
        d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        a = np.exp(-d2 / (2 * sigma * sigma)).astype(np.float32)
        np.fill_diagonal(a, 0.0)
        thr = np.sort(a, axis=1)[:, -kk]
        a_masked = np.where(a >= thr[:, None], a, 0.0)
        deg = np.asarray(op.degree)
        np.testing.assert_allclose(deg, a_masked.sum(1), rtol=1e-4)
        # and they are NOT the dense row sums — the pre-fix behaviour
        assert not np.allclose(deg, a.sum(1), rtol=1e-3)

    def test_residual_tol_composes_with_knn_spec(self):
        # the missing regression: residual_tol x knn_k ran to max_iter
        # before the truncated-residual fix. Modelled on
        # TestSubspaceResidualStopping (test_affinity_spec.py): col 1
        # stops on subspace convergence, col 0 stays pinned bitwise.
        from repro.data.synthetic import three_circles

        x, _ = three_circles(480, seed=0)
        spec = AffinitySpec(kind="rbf", sigma=0.3, knn_k=30)
        cfg = GPICConfig(affinity=spec, max_iter=400, n_vectors=2,
                         embedding="orthogonal")
        full = run_gpic(jnp.asarray(x), 3, cfg, key=jax.random.key(1))
        res = run_gpic(jnp.asarray(x), 3, cfg.with_(residual_tol=1e-3),
                       key=jax.random.key(1))
        assert int(full.n_iter_cols[1]) == 400
        assert int(res.n_iter_cols[1]) < 200
        assert bool(res.converged_cols.all())
        assert int(res.n_iter_cols[0]) == int(full.n_iter_cols[0])
        np.testing.assert_array_equal(np.asarray(res.embedding),
                                      np.asarray(full.embedding))


# ---------------------------------------------------------------------------
# k-means empty-cluster reseed (satellite)
# ---------------------------------------------------------------------------


class TestKmeansReseed:
    def test_adversarial_init_recovers_all_k(self):
        # three centroids inside one blob + one centroid far from every
        # point: the far one is empty on the first assignment. The old
        # keep-previous-centroid fix left it empty forever (k-1 distinct
        # labels); the farthest-point reseed recovers all k blobs.
        rs = np.random.RandomState(0)
        centers = [np.array(c, np.float32)
                   for c in ([0, 0], [8, 0], [0, 8], [8, 8])]
        x = np.concatenate([
            rs.randn(40, 2).astype(np.float32) * 0.05 + c for c in centers])
        init = jnp.asarray(
            np.array([[0, 0], [0.01, 0], [0, 0.01], [100, 100]], np.float32))
        labels, cents = kmeans(jax.random.key(0), jnp.asarray(x), 4,
                               iters=25, init=init)
        labels = np.asarray(labels)
        assert len(np.unique(labels)) == 4
        assert (np.bincount(labels) == 40).all()
        assert np.isfinite(np.asarray(cents)).all()

    def test_reseed_deterministic(self):
        rs = np.random.RandomState(1)
        x = jnp.asarray(rs.randn(64, 2).astype(np.float32))
        init = jnp.asarray(
            np.array([[0, 0], [50, 50], [60, 60]], np.float32))
        a, _ = kmeans(jax.random.key(0), x, 3, iters=10, init=init)
        b, _ = kmeans(jax.random.key(0), x, 3, iters=10, init=init)
        assert bool(jnp.all(a == b))

    def test_clean_path_unchanged(self):
        # with no empty clusters the reseed predicate is all-False: the
        # default kmeans++ path must be bitwise the historical one
        x = jnp.asarray(_blobs(64, k=3))
        labels, _ = kmeans(jax.random.key(0), x, 3)
        assert len(np.unique(np.asarray(labels))) == 3


# ---------------------------------------------------------------------------
# Fault-injection harness (train/fault_tolerance promoted to clustering)
# ---------------------------------------------------------------------------


class TestClusteringFaultHarness:
    def test_inject_nan_features(self):
        x = np.zeros((8, 3), np.float32)
        bad = inject_nan_features(x, [1, 4])
        assert bool(jnp.all(~jnp.isfinite(bad[1])))
        assert bool(jnp.all(~jnp.isfinite(bad[4])))
        assert bool(jnp.all(jnp.isfinite(bad[0])))

    def test_matrix_of_outcomes(self):
        x = _blobs(64, k=3)
        h = ClusteringFaultHarness(fail_at_trials=(1, 3))
        for trial in range(4):
            h.run_trial(trial, x, 3)
        statuses = [r["status"] for r in h.outcomes]
        # clean trials succeed clean; corrupted trials (NaN row) raise the
        # typed front-door error — nothing escapes as a crash or NaN labels
        assert statuses[0] == "ok" and statuses[2] == "ok"
        assert statuses[1] == "typed_error" and statuses[3] == "typed_error"
        assert h.outcomes[1]["error"] == "NonFiniteInputError"
        s = h.summary()
        assert s["trials"] == 4 and s["counts"]["typed_error"] == 2

    def test_degraded_outcome_with_sanitize(self):
        x = _blobs(64, k=3)
        h = ClusteringFaultHarness(fail_at_trials=(0,))
        rec = h.run_trial(0, x, 3, GPICConfig(sanitize=True))
        assert rec["status"] == "degraded"
        assert rec["health"]["notes"]
        assert np.isfinite(rec["labels"]).all()

    def test_ok_records_labels(self):
        rec = ClusteringFaultHarness().run_trial(0, _blobs(), 3)
        assert rec["status"] == "ok"
        assert len(np.unique(rec["labels"])) == 3


# ---------------------------------------------------------------------------
# Sharded: health parity + corrupted ring stage (8-device mesh subprocess)
# ---------------------------------------------------------------------------

_MESH_PRELUDE = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import AffinitySpec, GPICConfig, run_gpic
    from repro.core.distributed import distributed_gpic, shard_points
    from repro.core.health import raise_for_health, PowerDivergenceError
    from repro.data.synthetic import gaussians

    mesh = jax.make_mesh((8,), ("data",))
    """


def _mesh(body: str) -> str:
    return run_in_mesh_subprocess(
        textwrap.dedent(_MESH_PRELUDE) + textwrap.dedent(body))


@pytest.mark.slow
def test_sharded_health_parity():
    """Local and 8-device sharded runs of the same problem report IDENTICAL
    health diagnostics (col_status, isolated_rows, n_components, and the
    per-row component ids) — the probe's positivity pattern is reduction-
    order independent, so this parity is bitwise, for every engine."""
    out = _mesh("""
    rs = np.random.RandomState(0)
    x = np.concatenate([rs.randn(128, 2).astype(np.float32) * 0.1,
                        rs.randn(128, 2).astype(np.float32) * 0.1 + 50.0])
    xs = shard_points(x, mesh, "data")
    spec = AffinitySpec(kind="rbf", sigma=0.5, knn_k=8)
    for engine in ("explicit", "streaming"):
        cfg = GPICConfig(engine=engine, affinity=spec, n_vectors=2)
        key = jax.random.key(1)
        sd = run_gpic(jnp.asarray(x), 2, cfg, key=key)
        ds = run_gpic(xs, 2, cfg.with_(mesh=mesh), key=key)
        assert (np.asarray(sd.health.col_status)
                == np.asarray(ds.health.col_status)).all(), engine
        assert int(sd.health.isolated_rows) == int(ds.health.isolated_rows)
        assert int(sd.health.n_components) == int(ds.health.n_components) == 2
        assert (np.asarray(sd.health.components)
                == np.asarray(ds.health.components)).all(), engine
        print("OK", engine)
    # matrix-free (dense cosine): health parity with the probe unarmed
    x3 = gaussians(256, k=2, seed=0)[0]
    cfg = GPICConfig(engine="matrix_free", n_vectors=2)
    key = jax.random.key(1)
    sd = run_gpic(jnp.asarray(x3), 2, cfg, key=key)
    ds = run_gpic(shard_points(x3, mesh, "data"), 2, cfg.with_(mesh=mesh),
                  key=key)
    assert (np.asarray(sd.health.col_status)
            == np.asarray(ds.health.col_status)).all()
    assert int(sd.health.isolated_rows) == int(ds.health.isolated_rows) == 0
    assert int(sd.health.n_components) == int(ds.health.n_components) == -1
    print("OK matrix_free")
    """)
    assert out.count("OK") == 3


@pytest.mark.slow
def test_corrupted_ring_stage_is_latched():
    """A NaN-poisoned ring stage in the sharded streaming engine is caught
    by the non-finite column latch: the embedding comes back zeroed (not
    NaN), the health report says COL_NONFINITE, and promoting the report
    through raise_for_health yields the typed divergence error."""
    out = _mesh("""
    from repro.core.health import COL_NONFINITE
    x, _ = gaussians(256, k=3, seed=0)
    xs = shard_points(x, mesh, "data")
    res = distributed_gpic(xs, 3, key=jax.random.key(0), mesh=mesh,
                           engine="streaming", affinity_kind="rbf",
                           sigma=0.3, inject_ring_fault=("ring_nan", 2))
    status = np.asarray(res.health.col_status)
    assert (status & COL_NONFINITE).all(), status
    assert np.isfinite(np.asarray(res.embedding)).all()
    assert np.isfinite(np.asarray(res.embeddings)).all()
    try:
        raise_for_health(res.health, x.shape[0])
        raise AssertionError("expected PowerDivergenceError")
    except PowerDivergenceError:
        pass
    print("OK ring fault latched")
    # the hook validates its own arguments
    try:
        distributed_gpic(xs, 3, key=jax.random.key(0), mesh=mesh,
                         engine="explicit", affinity_kind="rbf", sigma=0.3,
                         inject_ring_fault=("ring_nan", 0))
        raise AssertionError("expected ValueError")
    except ValueError:
        print("OK non-ring engine rejected")
    """)
    assert out.count("OK") == 2


@pytest.mark.slow
def test_sharded_isolated_rows_and_clean_parity():
    """An underflow-isolated outlier row is counted identically by local
    and sharded engines, and the clean-input sharded labels stay intact."""
    out = _mesh("""
    rs = np.random.RandomState(1)
    x = np.concatenate([rs.randn(255, 2).astype(np.float32) * 0.2,
                        np.full((1, 2), 60.0, np.float32)])
    xs = shard_points(x, mesh, "data")
    cfg = GPICConfig(engine="streaming", affinity_kind="rbf", sigma=0.5)
    key = jax.random.key(1)
    sd = run_gpic(jnp.asarray(x), 2, cfg, key=key)
    ds = run_gpic(xs, 2, cfg.with_(mesh=mesh), key=key)
    assert int(sd.health.isolated_rows) == int(ds.health.isolated_rows) == 1
    assert np.isfinite(np.asarray(ds.embedding)).all()
    print("OK isolated parity")
    """)
    assert out.count("OK") == 1
