"""Clustering-quality regression suite for the three embedding modes.

Every cell runs the REAL front door (``run_gpic``, explicit Pallas engine)
on a scenario dataset and asserts an ARI floor. The floors are regression
bars set just under the measured values (seed 0, key 1 — the runs are
deterministic), not aspirations; the full measured table lives in
DESIGN.md §10. The headline row is three_circles × orthogonal: the 1-D
PIC embedding collapses two of the three concentric circles (ARI 0.811,
xfail'd since PR 1), while the orthogonalized 2-column block separates all
three (ARI 1.0) — the PR 3 acceptance case.

two_moons is intrinsically marginal at this sigma for every DENSE mode
(the classic baseline scores ~0.5); its dense floors document that no
mode regresses below the classic behaviour. The kNN-truncated affinity
spec (DESIGN.md §11) SOLVES it: the PR 5 acceptance class below asserts
ARI >= 0.9 (measured 1.0) at the same sigma 0.25 under
AffinitySpec(knn_k=30) with the orthogonal 2-column block — resolving the
ROADMAP two_moons item with an affinity idea, exactly as it predicted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AffinitySpec, GPICConfig, adjusted_rand_index, run_gpic
from repro.data import anisotropic, gaussians, three_circles, two_moons

#: (dataset, generator, k, rbf sigma)
DATASETS = {
    "blobs": (gaussians, 4, 0.3),
    "moons": (two_moons, 2, 0.25),
    "three_circles": (three_circles, 3, 0.3),
    "anisotropic": (anisotropic, 3, 0.3),
}

#: (embedding mode, n_vectors) — the mode's natural configuration: the
#: orthogonal block needs a second column to span nested structure; the
#: ensemble stacks diffusion times of the classic single vector.
MODES = {"pic": 1, "orthogonal": 2, "ensemble": 1}

#: ARI floors per (dataset, mode) — measured minus margin, see module doc.
FLOORS = {
    ("blobs", "pic"): 0.95,
    ("blobs", "orthogonal"): 0.95,
    ("blobs", "ensemble"): 0.95,
    ("moons", "pic"): 0.40,
    ("moons", "orthogonal"): 0.45,
    ("moons", "ensemble"): 0.35,
    ("three_circles", "pic"): 0.70,       # the documented 1-D limit
    ("three_circles", "orthogonal"): 0.90,  # the PR 3 acceptance bar
    ("three_circles", "ensemble"): 0.70,
    ("anisotropic", "pic"): 0.95,
    ("anisotropic", "orthogonal"): 0.95,
    ("anisotropic", "ensemble"): 0.95,
}


def _run(name: str, mode: str, **overrides):
    gen, k, sigma = DATASETS[name]
    x, y = gen(480, seed=0)
    cfg = GPICConfig(affinity_kind="rbf", sigma=sigma, max_iter=400,
                     n_vectors=MODES[mode], embedding=mode, **overrides)
    res = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
    return res, adjusted_rand_index(y, np.asarray(res.labels))


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("name", sorted(DATASETS))
def test_ari_floor(name, mode):
    res, ari = _run(name, mode)
    assert ari >= FLOORS[(name, mode)], (
        f"{name}/{mode}: ARI {ari:.3f} below floor {FLOORS[(name, mode)]}")
    assert res.embedding_mode == mode


def test_orthogonal_separates_three_circles():
    """The acceptance case: ARI >= 0.9 where the classic embedding scored
    0.811 — and the result records which embedding produced it."""
    res, ari = _run("three_circles", "orthogonal")
    assert ari >= 0.9
    assert res.embedding_mode == "orthogonal"
    assert res.embeddings.shape == (480, 2)
    # column 0 is still the classic pinned trajectory: its convergence
    # stats are the classic ones while the subspace column keeps iterating
    assert int(res.n_iter_cols[0]) < int(res.n_iter_cols[1])


def test_orthogonal_beats_classic_on_nested_structure():
    """The regression the mode exists to prevent: on concentric circles
    the orthogonalized block must strictly improve on the 1-D embedding."""
    _, ari_pic = _run("three_circles", "pic")
    _, ari_orth = _run("three_circles", "orthogonal")
    assert ari_orth > ari_pic


def test_ensemble_embedding_is_snapshot_stack():
    """Ensemble results carry the full (n, r·S) diffusion-time stack and
    the final state in the scalar back-compat fields."""
    res, _ = _run("blobs", "ensemble", snapshot_iters=(12, 50, 200, 400))
    assert res.embedding_mode == "ensemble"
    assert res.embeddings.shape == (480, 4)          # r=1, S=4
    # last snapshot column IS the final classic vector
    np.testing.assert_array_equal(np.asarray(res.embeddings[:, -1]),
                                  np.asarray(res.embedding))


def test_ensemble_scalar_fields_are_the_true_final_state():
    """A custom schedule ending BEFORE convergence must not leak a mid-run
    snapshot into the classic back-compat fields: embedding/n_iter are the
    loop's actual final state, identical to the mode='pic' run."""
    res_ens, _ = _run("blobs", "ensemble", snapshot_iters=(2, 4))
    res_pic, _ = _run("blobs", "pic")
    assert int(res_ens.n_iter) == int(res_pic.n_iter) > 4
    np.testing.assert_array_equal(np.asarray(res_ens.embedding),
                                  np.asarray(res_pic.embedding))
    # the stack still holds the early diffusion times, not the final state
    assert res_ens.embeddings.shape == (480, 2)
    assert not np.array_equal(np.asarray(res_ens.embeddings[:, 0]),
                              np.asarray(res_ens.embedding))


class TestKnnSpecQuality:
    """The PR 5 acceptance: kNN-truncated / adaptive affinity specs on the
    quality datasets, through the real front door. Floors are measured
    values (all 1.0) minus margin; the headline is two_moons — marginal
    for every dense mode (0.47-0.59), solved by graph truncation."""

    def _run(self, name, spec, r=2, embedding="orthogonal"):
        gen, k, _sigma = DATASETS[name]
        x, y = gen(480, seed=0)
        cfg = GPICConfig(affinity=spec, max_iter=400, n_vectors=r,
                         embedding=embedding)
        res = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
        return res, adjusted_rand_index(y, np.asarray(res.labels))

    def test_two_moons_knn_solved(self):
        """ARI >= 0.9 at sigma 0.25 where every dense mode is ~0.5."""
        res, ari = self._run(
            "moons", AffinitySpec(kind="rbf", sigma=0.25, knn_k=30))
        assert ari >= 0.9, f"moons under kNN spec: ARI {ari:.3f} < 0.9"

    def test_two_moons_adaptive_knn_solved(self):
        """The self-tuning route needs no sigma at all: adaptive local
        scales + a tighter kNN graph also score >= 0.9 (measured 1.0)."""
        _, ari = self._run(
            "moons", AffinitySpec(kind="rbf", bandwidth="adaptive",
                                  scale_k=7, knn_k=10))
        assert ari >= 0.9, f"moons under adaptive+kNN: ARI {ari:.3f} < 0.9"

    def test_three_circles_knn(self):
        """Truncation must not regress the PR 3 nested-structure result."""
        _, ari = self._run(
            "three_circles", AffinitySpec(kind="rbf", sigma=0.3, knn_k=30))
        assert ari >= 0.9

    def test_blobs_knn(self):
        _, ari = self._run(
            "blobs", AffinitySpec(kind="rbf", sigma=0.3, knn_k=10))
        assert ari >= 0.95

    def test_streaming_engine_matches_on_the_acceptance_case(self):
        """The moons win is engine-independent: the A-free streamed build
        clusters identically to the explicit masked matrix."""
        gen, k, _ = DATASETS["moons"]
        x, _y = gen(480, seed=0)
        spec = AffinitySpec(kind="rbf", sigma=0.25, knn_k=30)
        cfg = GPICConfig(affinity=spec, max_iter=400, n_vectors=2,
                         embedding="orthogonal")
        r_e = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
        r_s = run_gpic(jnp.asarray(x), k, cfg.with_(engine="streaming"),
                       key=jax.random.key(1))
        np.testing.assert_array_equal(np.asarray(r_e.labels),
                                      np.asarray(r_s.labels))


def test_qr_every_must_be_positive():
    """qr_every=0 would feed a modulo-zero predicate into the loop; the
    front door and the engine both reject it."""
    from repro.core import batched_power_iteration
    x, _ = DATASETS["blobs"][0](64, seed=0)
    with pytest.raises(ValueError, match="qr_every"):
        run_gpic(jnp.asarray(x), 2,
                 GPICConfig(embedding="orthogonal", n_vectors=2, qr_every=0),
                 key=jax.random.key(0))
    with pytest.raises(ValueError, match="qr_every"):
        batched_power_iteration(lambda v: v, jnp.ones((8, 2)), 1e-5, 5,
                                mode="orthogonal", qr_every=0)


def test_quality_matrix_consistent_across_engines():
    """The mode routing is engine-independent: streaming (A-free) produces
    the same orthogonal-mode labels as the explicit build on the
    acceptance dataset."""
    gen, k, sigma = DATASETS["three_circles"]
    x, y = gen(480, seed=0)
    cfg = GPICConfig(affinity_kind="rbf", sigma=sigma, max_iter=400,
                     n_vectors=2, embedding="orthogonal")
    res_e = run_gpic(jnp.asarray(x), k, cfg, key=jax.random.key(1))
    res_s = run_gpic(jnp.asarray(x), k, cfg.with_(engine="streaming"),
                     key=jax.random.key(1))
    np.testing.assert_array_equal(np.asarray(res_e.labels),
                                  np.asarray(res_s.labels))
