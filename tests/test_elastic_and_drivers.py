"""Elastic rescaling (checkpoint -> different mesh) + CLI driver tests."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(__file__))
REPO_SRC = os.path.join(REPO, "src")


def _run(code=None, argv=None, timeout=580, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.update(env_extra or {})
    cmd = ([sys.executable, "-c", code] if code
           else [sys.executable] + argv)
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=REPO)
    assert out.returncode == 0, f"STDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_elastic_reshard_8_to_4_devices(tmp_path):
    """Save a sharded train state on an 8-device mesh, restore it onto a
    4-device mesh (the elastic scale-down path), continue training, and
    match a never-resharded run (float-association tolerance: different DP
    widths reduce the batch in different orders)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import TrainConfig, get_smoke_config
        from repro.models import get_api, make_train_batch
        from repro.train import adamw_init, build_train_step
        from repro.train import checkpoint as ckpt
        from repro.distributed.sharding import axis_rules, logical_to_spec
        from repro.launch.mesh import param_shardings

        cfg = get_smoke_config("stablelm-3b")
        tcfg = TrainConfig(compute_dtype="float32", remat="none",
                           learning_rate=1e-3, warmup_steps=2, total_steps=50)
        api = get_api(cfg)
        rules = {{"batch": ("data",), "heads": "model", "kv_heads": "model",
                  "mlp": "model", "vocab": "model", "embed": None,
                  "layers": None, "heads_act": "model", "kv_heads_act": "model",
                  "seq": None}}
        step = build_train_step(cfg, tcfg)

        def train_n(mesh, state, steps, start):
            with mesh, axis_rules(rules, mesh=mesh):
                p_sh = param_shardings(mesh, api.param_specs(cfg))
                jit_step = jax.jit(step)
                params, opt = state
                params = jax.device_put(params, p_sh)
                for i in range(start, start + steps):
                    batch = make_train_batch(cfg, 4, 16, 1000 + i)
                    params, opt, _ = jit_step(params, opt, batch)
                return params, opt

        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])

        params = api.init_params(jax.random.key(0), cfg)
        opt = adamw_init(params)

        # run A: 4 steps on mesh8, checkpoint, 4 more on mesh8
        pa, oa = train_n(mesh8, (params, opt), 4, 0)
        ckpt.save(r"{tmp_path}/step4", (pa, oa), step=4)
        pa, oa = train_n(mesh8, (pa, oa), 4, 4)

        # run B: restore the checkpoint onto mesh4 (ELASTIC RESHARD), resume
        restored, s = ckpt.restore(r"{tmp_path}/step4",
                                   jax.tree.map(lambda x: x, (pa, oa)))
        with mesh4, axis_rules(rules, mesh=mesh4):
            p_sh4 = param_shardings(mesh4, api.param_specs(cfg))
            pb = jax.device_put(restored[0], p_sh4)
            ob = restored[1]
        pb, ob = train_n(mesh4, (pb, ob), 4, 4)

        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=2e-3)
        print("ELASTIC-OK")
    """)
    out = _run(code=code)
    assert "ELASTIC-OK" in out


@pytest.mark.slow
def test_train_driver_cli(tmp_path):
    out = _run(argv=["-m", "repro.launch.train", "--arch", "qwen1.5-4b",
                     "--smoke", "--steps", "6", "--batch", "2", "--seq", "32",
                     "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    assert "done: 6 steps" in out
    assert os.path.exists(os.path.join(str(tmp_path), "summary.json"))


@pytest.mark.slow
def test_train_driver_survives_injected_failure(tmp_path):
    out = _run(argv=["-m", "repro.launch.train", "--arch", "stablelm-3b",
                     "--smoke", "--steps", "8", "--batch", "2", "--seq", "32",
                     "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
                     "--inject-failure-at", "5"])
    assert "restarts=1" in out


@pytest.mark.slow
def test_serve_driver_cli():
    out = _run(argv=["-m", "repro.launch.serve", "--arch", "mamba2-780m",
                     "--smoke", "--batch", "2", "--prompt-len", "16",
                     "--gen", "4"])
    assert "decode:" in out
