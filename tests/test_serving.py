"""Serving-path integration tests: prefill->decode consistency and the
flash-decoding kernel under sharding rules (subprocess, 8 devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import get_api, make_train_batch

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


@pytest.mark.parametrize("arch", ["stablelm-3b", "h2o-danube-3-4b",
                                  "mamba2-780m", "zamba2-2.7b",
                                  "deepseek-v2-lite-16b"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Greedy next-token from [prefill + decode] must match a full forward
    over the extended sequence (cache correctness)."""
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0), cfg)
    batch = make_train_batch(cfg, 2, 17, 0)
    tokens = batch["tokens"]

    # full forward over all 17 tokens: logits at position 16 predict token 17
    full = api.forward(params, cfg, batch, compute_dtype=jnp.float32)

    # prefill 16 then decode token 16
    batch16 = dict(batch)
    batch16["tokens"] = tokens[:, :16]
    out = api.prefill(params, cfg, batch16, 32, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    logits_p, cache = out[0], out[1]
    extras = {"enc_out": out[2]} if cfg.family == "encdec" else None
    step_logits, _ = api.decode_step(
        params, cfg, tokens[:, 16:17], cache, jnp.int32(16), extras,
        compute_dtype=jnp.float32)

    np.testing.assert_allclose(
        np.asarray(full[:, 16]), np.asarray(step_logits[:, 0]),
        atol=2e-3, rtol=1e-3)


def test_flash_decode_matches_dense_under_mesh():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import get_api, make_train_batch
        from repro.distributed.sharding import axis_rules

        cfg = get_smoke_config("granite-34b")   # MQA
        api = get_api(cfg)
        params = api.init_params(jax.random.key(0), cfg)
        batch = make_train_batch(cfg, 2, 16, 0)
        _, cache = api.prefill(params, cfg, batch, 32,
                               compute_dtype=jnp.float32,
                               cache_dtype=jnp.float32)
        tok = batch["tokens"][:, -1:]
        ref, _ = api.decode_step(params, cfg, tok, cache, jnp.int32(16), None,
                                 compute_dtype=jnp.float32)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = {"batch": ("data",), "cache_seq": ("model",),
                 "heads_act": None, "kv_heads_act": None, "embed": None,
                 "vocab": None, "heads": None, "kv_heads": None,
                 "mlp": None, "layers": None, "seq": None}
        with mesh, axis_rules(rules, mesh=mesh):
            out, _ = jax.jit(lambda p, t, c, pos: api.decode_step(
                p, cfg, t, c, pos, None, compute_dtype=jnp.float32)
            )(params, tok, cache, jnp.int32(16))
        err = float(jnp.max(jnp.abs(ref - out)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_ep_moe_matches_local_under_mesh():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models.moe import moe_ffn, init_moe_ffn, _moe_ffn_local
        from repro.distributed.sharding import axis_rules
        cfg = get_smoke_config("deepseek-v2-lite-16b")
        p = init_moe_ffn(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model)) * 0.5
        y_local, _ = _moe_ffn_local(x, p, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = {"batch": ("data",), "experts": "model", "mlp": None,
                 "embed": None, "expert_mlp": None, "seq": None}
        with mesh, axis_rules(rules, mesh=mesh):
            y_ep, _ = jax.jit(lambda x, p: moe_ffn(x, p, cfg))(x, p)
        frac = float(jnp.mean((jnp.abs(y_local - y_ep) < 1e-4)
                              .astype(jnp.float32)))
        assert frac > 0.97, frac   # capacity-drop sets may differ slightly
        print("OK", frac)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
