"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, TrainConfig, get_config, get_smoke_config
from repro.models import get_api, make_train_batch
from repro.train import adamw_init, build_train_step

TCFG = TrainConfig(compute_dtype="float32", param_dtype="float32",
                   remat="none", learning_rate=1e-3, warmup_steps=2,
                   total_steps=10, z_loss=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0), cfg)
    batch = make_train_batch(cfg, 2, 32, 0)
    logits = api.forward(params, cfg, batch, compute_dtype=jnp.float32)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0), cfg)
    opt = adamw_init(params)
    batch = make_train_batch(cfg, 2, 32, 1)
    step = jax.jit(build_train_step(cfg, TCFG))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), "non-finite loss"
    assert np.isfinite(float(metrics["grad_norm"])), "non-finite grad norm"
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params2),
                                jax.tree.leaves(params)))
    assert delta > 0.0
    # structure preserved
    assert (jax.tree_util.tree_structure(params2)
            == jax.tree_util.tree_structure(params))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0), cfg)
    batch = make_train_batch(cfg, 2, 16, 2)
    out = api.prefill(params, cfg, batch, 32, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    logits, cache = out[0], out[1]
    extras = {"enc_out": out[2]} if cfg.family == "encdec" else None
    pos = jnp.int32(16 + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0))
    logits2, cache2 = api.decode_step(
        params, cfg, batch["tokens"][:, -1:], cache, pos, extras,
        compute_dtype=jnp.float32)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims(arch):
    """The exact assigned dimensions are preserved in the full configs."""
    expected = {
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected
    if arch == "zamba2-2.7b":
        assert cfg.ssm.d_state == 64
    if arch == "mamba2-780m":
        assert cfg.ssm.d_state == 128
    if arch == "deepseek-v2-lite-16b":
        assert cfg.mla.kv_lora_rank == 512
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 1
    if arch == "qwen1.5-4b":
        assert cfg.qkv_bias
    if arch == "h2o-danube-3-4b":
        assert cfg.sliding_window > 0


def test_microbatched_step_matches_single_shot():
    """Grad accumulation must match the unsplit step (same total batch)."""
    cfg = get_smoke_config("stablelm-3b")
    api = get_api(cfg)
    params = api.init_params(jax.random.key(0), cfg)
    opt = adamw_init(params)
    batch = make_train_batch(cfg, 4, 16, 3)

    s1 = jax.jit(build_train_step(cfg, TCFG))
    s2 = jax.jit(build_train_step(
        cfg, TrainConfig(**{**TCFG.__dict__, "microbatch": 2})))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-5)
    # Adam's sqrt(v)-normalization amplifies f32 association noise — 2e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
