"""Block-CSR stripe sweeps + fused one-pass build (ISSUE 8 tentpole).

Four layers, each pinned bitwise against the dense-storage truncated path
it shadows (the DESIGN.md §13 discipline — skipped steps gather DEAD
all-zero blocks, so the accumulation ORDER and step program are identical
to the dense grid's and the results match bit for bit):

  TestBlockPlan       the (counts, col_idx, max_b) plan itself: roundtrip
                      through plan_to_live, and the property that the plan
                      covers EXACTLY the blocks the top-k mask keeps
  TestKernelParity    each block-sparse kernel vs its dense-grid twin at
                      matching pinned tiles, r ∈ {1, 4}, plus the
                      reference-oracle agreement
  TestFusedBuild      fused_affinity_build (one pass over the feature
                      blocks) vs the two-pass build-then-rebuild: a, d,
                      and the per-row thresholds all bitwise
  TestEnginePath      run_gpic(block_sparse=True) vs the dense-storage
                      path per engine — labels, embeddings, n_iter_cols —
                      including the degenerate single-column-block grid
                      that must fall back to the dense kernel, and the
                      matrix-free rejection of truncated specs

plus the 8-device mesh parity case (slow): sharded block-sparse ==
sharded dense-storage bitwise for both engines at tile=32 (stage grids
2x2, so the ring's stacked liveness plan is genuinely exercised).

Data is CLUSTER-SORTED blobs so kNN truncation kills whole off-diagonal
tile blocks — the plan must actually skip steps for these tests to mean
anything (asserted, not assumed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import textwrap

from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import run_in_mesh_subprocess
from repro.core import AffinitySpec, GPICConfig, run_gpic
from repro.core.affinity import block_plan, dense_block_live, plan_to_live
from repro.core.graph import affinity_stats, fused_affinity_build
from repro.kernels import ops

KNN = AffinitySpec(kind="rbf", sigma=0.5, knn_k=10)
ADA_KNN = AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=5,
                       knn_k=10)


def _blobs(n=192, m=8, k=3, seed=0):
    """Cluster-sorted well-separated blobs: rows of the same cluster are
    contiguous, so truncation leaves dead off-diagonal tile blocks."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-20.0, 20.0, (k, m))
    x = np.concatenate([
        centers[i] + 0.5 * rng.standard_normal((n // k, m))
        for i in range(k)
    ])
    return jnp.asarray(x, jnp.float32)


def _built(spec, n=192, tm=64, tn=64):
    """Dense-storage truncated (a, d) + pass-1 stats on pinned tiles."""
    x = _blobs(n)
    scale, thr = affinity_stats(x, spec)
    a, d = ops.affinity_and_degree(x, spec=spec, scale_r=scale,
                                   scale_c=scale, thr=thr, tm=tm, tn=tn)
    return x, scale, thr, a, d


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


class TestBlockPlan:
    def test_roundtrip_handmade(self):
        live = jnp.asarray([[1, 0, 1, 0],
                            [0, 0, 0, 0],
                            [1, 1, 1, 1]], jnp.int32)
        counts, col_idx, max_b = block_plan(live)
        assert counts.tolist() == [2, 0, 4]
        assert int(max_b) == 4
        # ascending live ids first; the dead tail stays in-range
        assert col_idx[0, :2].tolist() == [0, 2]
        assert sorted(col_idx[1].tolist()) == [0, 1, 2, 3]
        np.testing.assert_array_equal(
            np.asarray(plan_to_live(counts, col_idx)),
            np.asarray(live) != 0)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 2**31 - 1))
    def test_roundtrip_property(self, n_i, n_j, seed):
        live = np.random.RandomState(seed).rand(n_i, n_j) < 0.4
        counts, col_idx, max_b = block_plan(jnp.asarray(live))
        np.testing.assert_array_equal(np.asarray(plan_to_live(counts,
                                                              col_idx)),
                                      live)
        np.testing.assert_array_equal(np.asarray(counts),
                                      live.sum(axis=1))
        assert int(max_b) == max(int(live.sum(axis=1).max()), 1)
        ci = np.asarray(col_idx)
        for i in range(n_i):
            # every row is a permutation of the block ids (dead tail is
            # still valid for the DMA index maps) with live ids ascending
            assert sorted(ci[i].tolist()) == list(range(n_j))
            lead = ci[i, :live[i].sum()]
            assert (lead == np.sort(np.where(live[i])[0])).all()

    def test_plan_covers_exactly_the_topk_mask(self):
        """Satellite 4 property: the plan's live blocks are EXACTLY the
        tiles holding entries the top-k mask kept — no survivor outside a
        live block, no live block without a survivor."""
        tm = tn = 64
        _, _, _, a, _ = _built(KNN, tm=tm, tn=tn)
        an = np.asarray(a)
        live = np.asarray(dense_block_live(a, tm, tn))
        counts, col_idx, _ = block_plan(jnp.asarray(live))
        planned = np.asarray(plan_to_live(counts, col_idx))
        for i in range(live.shape[0]):
            for j in range(live.shape[1]):
                tile_nnz = (an[i * tm:(i + 1) * tm,
                               j * tn:(j + 1) * tn] != 0).any()
                assert bool(planned[i, j]) == bool(tile_nnz), (i, j)


# ---------------------------------------------------------------------------
# kernel-level bitwise parity vs the dense-grid twins
# ---------------------------------------------------------------------------


class TestKernelParity:
    def _plan(self, a, tm, tn):
        live = dense_block_live(a, tm, tn)
        counts, col_idx, max_b = block_plan(live)
        # the data must produce real sparsity or these tests test nothing
        assert int(max_b) < live.shape[1], "no dead blocks — fixture broken"
        return counts, col_idx, max_b

    @pytest.mark.parametrize("r", [1, 4])
    def test_matmat_bitwise(self, r):
        tm = tn = 64
        _, _, _, a, d = _built(KNN, tm=tm, tn=tn)
        counts, col_idx, max_b = self._plan(a, tm, tn)
        v = jax.random.uniform(jax.random.key(r), (a.shape[1], r),
                               jnp.float32)
        got = ops.block_sparse_matmat(a, v, d, counts, col_idx, max_b,
                                      tm=tm, tn=tn)
        want = ops.degree_normalized_matmat(a, v, d, tm=tm, tn=tn)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("spec", [KNN, ADA_KNN], ids=["knn", "ada+knn"])
    @pytest.mark.parametrize("r", [1, 4])
    def test_streaming_matmat_bitwise(self, r, spec):
        tm = tn = 64
        x, scale, thr, a, d = _built(spec, tm=tm, tn=tn)
        counts, col_idx, max_b = self._plan(a, tm, tn)
        v = jax.random.uniform(jax.random.key(r), (x.shape[0], r),
                               jnp.float32)
        got = ops.block_sparse_streaming_matmat(
            x, v, d, counts=counts, col_idx=col_idx, max_b=max_b,
            spec=spec, scale_r=scale, scale_c=scale, thr=thr, tm=tm, tn=tn)
        want = ops.streaming_matmat(x, v, d, spec=spec, scale_r=scale,
                                    scale_c=scale, thr=thr, tm=tm, tn=tn)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_streaming_degree_bitwise(self):
        tm = tn = 64
        x, scale, thr, a, _ = _built(KNN, tm=tm, tn=tn)
        counts, col_idx, max_b = self._plan(a, tm, tn)
        got = ops.block_sparse_streaming_degree(
            x, counts=counts, col_idx=col_idx, max_b=max_b, spec=KNN,
            scale_r=scale, scale_c=scale, thr=thr, tm=tm, tn=tn)
        want = ops.streaming_degree(x, spec=KNN, scale_r=scale,
                                    scale_c=scale, thr=thr, tm=tm, tn=tn)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_liveness_matches_stored_matrix(self):
        """The A-free liveness pass sees the same live map the explicit
        engine reads off the matrix it stored."""
        tm = tn = 64
        x, scale, thr, a, _ = _built(KNN, tm=tm, tn=tn)
        got = ops.block_liveness(x, spec=KNN, scale_r=scale, scale_c=scale,
                                 thr=thr, tm=tm, tn=tn)
        np.testing.assert_array_equal(
            np.asarray(got) != 0, np.asarray(dense_block_live(a, tm, tn)))

    def test_reference_oracles_agree(self):
        """force_reference=True routes to kernels/ref.py — same math,
        unfused HLO; the fallback path must agree with the kernels."""
        tm = tn = 64
        x, scale, thr, a, d = _built(KNN, tm=tm, tn=tn)
        counts, col_idx, max_b = self._plan(a, tm, tn)
        v = jax.random.uniform(jax.random.key(0), (x.shape[0], 2),
                               jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.block_sparse_matmat(
                a, v, d, counts, col_idx, max_b, tm=tm, tn=tn,
                force_reference=True)),
            np.asarray(ops.block_sparse_matmat(
                a, v, d, counts, col_idx, max_b, tm=tm, tn=tn)),
            rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(ops.block_sparse_streaming_matmat(
                x, v, d, counts=counts, col_idx=col_idx, max_b=max_b,
                spec=KNN, scale_r=scale, scale_c=scale, thr=thr,
                tm=tm, tn=tn, force_reference=True)),
            np.asarray(ops.block_sparse_streaming_matmat(
                x, v, d, counts=counts, col_idx=col_idx, max_b=max_b,
                spec=KNN, scale_r=scale, scale_c=scale, thr=thr,
                tm=tm, tn=tn)),
            rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(
            np.asarray(ops.block_liveness(
                x, spec=KNN, scale_r=scale, scale_c=scale, thr=thr,
                tm=tm, tn=tn, force_reference=True)) != 0,
            np.asarray(ops.block_liveness(
                x, spec=KNN, scale_r=scale, scale_c=scale, thr=thr,
                tm=tm, tn=tn)) != 0)


# ---------------------------------------------------------------------------
# the fused one-pass build
# ---------------------------------------------------------------------------


class TestFusedBuild:
    @pytest.mark.parametrize("spec", [KNN, ADA_KNN], ids=["knn", "ada+knn"])
    def test_matches_two_pass_bitwise(self, spec):
        tm = tn = 64
        x = _blobs()
        scale, thr2 = affinity_stats(x, spec)
        a2, d2 = ops.affinity_and_degree(x, spec=spec, scale_r=scale,
                                         scale_c=scale, thr=thr2,
                                         tm=tm, tn=tn)
        a1, d1, thr1 = fused_affinity_build(x, spec=spec, scale_r=scale,
                                            scale_c=scale, tm=tm, tn=tn)
        np.testing.assert_array_equal(np.asarray(thr1), np.asarray(thr2))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))

    def test_bf16_storage_matches(self):
        tm = tn = 64
        x = _blobs()
        scale, thr2 = affinity_stats(x, KNN)
        a2, d2 = ops.affinity_and_degree(x, spec=KNN, scale_r=scale,
                                         scale_c=scale, thr=thr2,
                                         tm=tm, tn=tn,
                                         out_dtype=jnp.bfloat16)
        a1, d1, _ = fused_affinity_build(x, spec=KNN, scale_r=scale,
                                         scale_c=scale, tm=tm, tn=tn,
                                         a_dtype=jnp.bfloat16)
        assert a1.dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(a1, jnp.float32), np.asarray(a2, jnp.float32))
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


# ---------------------------------------------------------------------------
# engine-level: run_gpic block_sparse=True vs the dense-storage path
# ---------------------------------------------------------------------------


def _bitwise(res_a, res_b, ctx):
    np.testing.assert_array_equal(np.asarray(res_a.labels),
                                  np.asarray(res_b.labels), err_msg=str(ctx))
    np.testing.assert_array_equal(np.asarray(res_a.embeddings),
                                  np.asarray(res_b.embeddings),
                                  err_msg=str(ctx))
    np.testing.assert_array_equal(np.asarray(res_a.n_iter_cols),
                                  np.asarray(res_b.n_iter_cols),
                                  err_msg=str(ctx))


class TestEnginePath:
    @pytest.mark.parametrize("engine", ["explicit", "streaming"])
    @pytest.mark.parametrize("r", [1, 4])
    def test_block_sparse_is_bitwise_vs_dense_storage(self, engine, r):
        x = _blobs()
        cfg = GPICConfig(engine=engine, affinity=ADA_KNN, n_vectors=r,
                         max_iter=60, tile=64)
        key = jax.random.key(1)
        bs = run_gpic(x, 3, cfg, key=key)
        dn = run_gpic(x, 3, cfg.with_(block_sparse=False), key=key)
        _bitwise(bs, dn, (engine, r))
        assert int(bs.health.n_components) == int(dn.health.n_components)

    def test_degenerate_grid_falls_back_bitwise(self):
        """tile >= n gives a single column block: nothing to skip, and the
        operator must keep the dense-grid kernel (the guard that pins the
        r=1 fusion form — DESIGN.md §13)."""
        x = _blobs()
        cfg = GPICConfig(engine="streaming", affinity=KNN, n_vectors=1,
                         max_iter=60, tile=256)
        key = jax.random.key(1)
        _bitwise(run_gpic(x, 3, cfg, key=key),
                 run_gpic(x, 3, cfg.with_(block_sparse=False), key=key),
                 "degenerate")

    def test_matrix_free_rejects_truncated_spec(self):
        x = _blobs()
        for bs in (True, False):
            with pytest.raises(ValueError, match="factorable"):
                run_gpic(x, 3, GPICConfig(engine="matrix_free",
                                          affinity=KNN, block_sparse=bs),
                         key=jax.random.key(1))


# ---------------------------------------------------------------------------
# 8-device mesh parity (satellite 4)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_block_sparse_parity():
    """Sharded block-sparse vs sharded dense-storage for both engines x
    r in {1, 4} at tile=32 — n_loc=64 gives every ring stage a 2x2 block
    grid, so the stacked liveness plan and per-stage gathers run for real.

    Both engines are asserted fully BITWISE against their dense-storage
    twins: labels, embeddings, n_iter_cols. This is also the regression
    net for the argsort-under-shard_map miscompile (the sort-free
    block_plan, core/affinity.py): with the sorted plan, every device
    whose live blocks sit off the leading diagonal read dead stripe
    tiles and the power iteration collapsed onto one component. The
    matrix-free engine's truncated-spec rejection holds on the mesh
    too."""
    out = run_in_mesh_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import AffinitySpec, GPICConfig, run_gpic
        from repro.core.distributed import shard_points

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        centers = rng.uniform(-20.0, 20.0, (4, 8))
        x = np.concatenate([
            centers[i] + 0.5 * rng.standard_normal((128, 8))
            for i in range(4)
        ]).astype(np.float32)
        xs = shard_points(x, mesh, "data")
        spec = AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=5,
                            knn_k=10)
        for engine in ("explicit", "streaming"):
            for r in (1, 4):
                cfg = GPICConfig(engine=engine, affinity=spec, n_vectors=r,
                                 max_iter=60, tile=32, mesh=mesh)
                key = jax.random.key(1)
                bs = run_gpic(xs, 4, cfg, key=key)
                dn = run_gpic(xs, 4, cfg.with_(block_sparse=False), key=key)
                assert (np.asarray(bs.labels)
                        == np.asarray(dn.labels)).all(), (engine, r)
                assert (np.asarray(bs.embeddings)
                        == np.asarray(dn.embeddings)).all(), (engine, r)
                assert (np.asarray(bs.n_iter_cols)
                        == np.asarray(dn.n_iter_cols)).all(), (engine, r)
                assert (int(bs.health.n_components)
                        == int(dn.health.n_components) == 4), (engine, r)
                assert (int(bs.health.isolated_rows)
                        == int(dn.health.isolated_rows)), (engine, r)
                print("OK", engine, "r=", r)
        try:
            run_gpic(xs, 4, GPICConfig(engine="matrix_free", affinity=spec,
                                       mesh=mesh), key=jax.random.key(1))
        except ValueError as e:
            assert "factorable" in str(e)
            print("OK matrix_free-rejects-knn")
        """))
    assert out.count("OK") == 5
