"""The PR-9 resumable-execution suite: bitwise resume parity + supervisor.

The hard guarantee under test: segmentation only moves where the power
while_loop STOPS, never what a sweep computes — so a run interrupted at
ANY sweep and resumed from its snapshot is bitwise identical (labels,
embeddings, per-column iteration counts, health latches) to the
uninterrupted run, for every engine, locally and on the 8-device mesh
(DESIGN.md §14). Around that core:

  checkpointed == plain   supervised runs with snapshots every few sweeps
                          return the monolithic result bitwise
  interrupt + resume      injected SimulatedFailure at a sweep; the
                          supervisor restores the newest snapshot and the
                          final result matches the uninterrupted baseline
  kill + fresh call       a run that dies (max_retries=0) leaves snapshots
                          a NEW run_gpic call resumes from (resumed:<t>)
  corrupt snapshots       checksum-failing snapshots are quarantined and
                          the supervisor falls back to the previous valid
                          step (checkpoint_skipped note) — still bitwise
  straggler watchdog      a segment over budget raises the typed
                          StragglerTimeout, consumed by the retry loop
  concurrent faults       multi-fault schedules (isolated rows + forced
                          kernel failure + injected sweep failures; ring
                          NaN + isolated rows on the mesh) land on the
                          contracted outcome per class — never a crash

Mesh tests run in the 8-host-device subprocess harness (same as
test_robustness.py) and are marked slow.
"""
import os
import shutil
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_in_mesh_subprocess
from repro.core import (
    AffinitySpec,
    CheckpointCorruptError,
    GPICConfig,
    GPICError,
    StragglerTimeout,
    is_recovery_note,
    run_gpic,
)
from repro.data.synthetic import gaussians
from repro.train.fault_tolerance import (
    FailureInjector,
    FaultSchedule,
    SimulatedFailure,
    apply_feature_faults,
    run_schedule,
)


def _blobs(n=96, k=3, seed=0):
    return gaussians(n, k=k, seed=seed)[0]


def _fields(res):
    return tuple(np.asarray(jax.device_get(a)) for a in (
        res.labels, res.embeddings, res.n_iter_cols, res.converged_cols,
        res.health.col_status, res.health.isolated_rows))


def _assert_bitwise(a, b, ctx=""):
    names = ("labels", "embeddings", "n_iter_cols", "converged_cols",
             "col_status", "isolated_rows")
    for name, fa, fb in zip(names, _fields(a), _fields(b)):
        assert np.array_equal(fa, fb), f"{ctx}: {name} differs"


# ---------------------------------------------------------------------------
# Local: checkpointed / interrupted / resumed runs are bitwise the plain run
# ---------------------------------------------------------------------------


class TestLocalResumeParity:
    CASES = [
        ("explicit", "pic", 1),
        ("explicit", "ensemble", 2),
        ("streaming", "orthogonal", 4),
        ("matrix_free", "pic", 2),
    ]

    @pytest.mark.parametrize("engine,embedding,r", CASES)
    def test_checkpointed_equals_plain(self, tmp_path, engine, embedding, r):
        x = _blobs()
        cfg = GPICConfig(engine=engine, embedding=embedding, n_vectors=r,
                         max_iter=30)
        base = run_gpic(x, 3, cfg)
        sup = run_gpic(x, 3, cfg.with_(checkpoint_every=7,
                                       ckpt_dir=str(tmp_path / "ck")))
        _assert_bitwise(base, sup, f"{engine}/{embedding}/r={r}")
        assert sup.health.notes == ()  # an undisturbed run leaves no trace

    @pytest.mark.parametrize("engine,embedding,r", CASES)
    def test_interrupted_and_resumed_is_bitwise(self, tmp_path, engine,
                                                embedding, r):
        x = _blobs()
        cfg = GPICConfig(engine=engine, embedding=embedding, n_vectors=r,
                         max_iter=30)
        base = run_gpic(x, 3, cfg)
        inj = FailureInjector(fail_at_steps=(7,))
        res = run_gpic(x, 3, cfg.with_(checkpoint_every=7,
                                       ckpt_dir=str(tmp_path / "ck")),
                       segment_injector=inj.maybe_fail)
        _assert_bitwise(base, res, f"{engine}/{embedding}/r={r}")
        assert "retry:1:SimulatedFailure" in res.health.notes
        assert "resumed:7" in res.health.notes
        assert all(is_recovery_note(n) for n in res.health.notes)

    def test_kill_then_fresh_call_resumes(self, tmp_path):
        """A run that exhausts its retries leaves snapshots on disk; the
        next run_gpic call with the same ckpt_dir resumes instead of
        restarting — the cross-process resume path, bitwise."""
        x = _blobs()
        # eps_scale=1e-7 keeps the run alive ~19 sweeps so the boundary-10
        # injection fires before convergence breaks the segment loop
        cfg = GPICConfig(max_iter=30, eps_scale=1e-7, checkpoint_every=5,
                         ckpt_dir=str(tmp_path / "ck"), max_retries=0)
        inj = FailureInjector(fail_at_steps=(10,))
        with pytest.raises(SimulatedFailure):
            run_gpic(x, 3, cfg, segment_injector=inj.maybe_fail)
        res = run_gpic(x, 3, cfg)
        base = run_gpic(x, 3, GPICConfig(max_iter=30, eps_scale=1e-7))
        _assert_bitwise(base, res, "kill+rerun")
        assert "resumed:10" in res.health.notes

    def test_corrupt_snapshot_skips_to_previous_valid(self, tmp_path):
        """Flipping bytes in the newest snapshot's leaf trips the per-leaf
        checksum; the supervisor quarantines it, resumes from the previous
        valid step, and still reproduces the baseline bitwise."""
        x = _blobs()
        root = str(tmp_path / "ck")
        cfg = GPICConfig(max_iter=30, eps_scale=1e-7, checkpoint_every=5,
                         ckpt_dir=root, max_retries=0)
        inj = FailureInjector(fail_at_steps=(10,))
        with pytest.raises(SimulatedFailure):
            run_gpic(x, 3, cfg, segment_injector=inj.maybe_fail)
        newest = sorted(d for d in os.listdir(root)
                        if d.startswith("step_"))[-1]
        leaf = os.path.join(root, newest, "leaf_00001.npy")
        raw = bytearray(open(leaf, "rb").read())
        raw[-32:] = b"\xff" * 32
        open(leaf, "wb").write(bytes(raw))
        res = run_gpic(x, 3, cfg)
        base = run_gpic(x, 3, GPICConfig(max_iter=30, eps_scale=1e-7))
        _assert_bitwise(base, res, "corrupt-skip")
        assert f"checkpoint_skipped:{newest}" in res.health.notes
        assert any(n.startswith("resumed:") for n in res.health.notes)
        # the corrupt dir is quarantined, not deleted
        assert os.path.isdir(os.path.join(root, "corrupt_" + newest))

    def test_every_interrupt_sweep_is_bitwise(self, tmp_path):
        """Snapshot every sweep and interrupt at {1, mid, last-1}: resume
        parity must hold at ANY boundary, not just multiples of a coarse
        cadence."""
        x = _blobs()
        base_cfg = GPICConfig(max_iter=30)
        base = run_gpic(x, 3, base_cfg)
        t_final = int(np.max(np.asarray(base.n_iter_cols)))
        assert t_final > 3  # the three interrupt points must be distinct
        for s in (1, t_final // 2, t_final - 1):
            d = str(tmp_path / f"ck{s}")
            inj = FailureInjector(fail_at_steps=(s,))
            res = run_gpic(x, 3,
                           base_cfg.with_(checkpoint_every=1, ckpt_dir=d),
                           segment_injector=inj.maybe_fail)
            _assert_bitwise(base, res, f"interrupt@{s}")
            assert f"resumed:{s}" in res.health.notes

    def test_straggler_timeout_is_typed_and_retried(self):
        x = _blobs()
        with pytest.raises(StragglerTimeout):
            run_gpic(x, 3, GPICConfig(max_iter=30, straggler_timeout=1e-9,
                                      max_retries=2))

    def test_straggler_timeout_with_headroom_passes(self):
        x = _blobs()
        res = run_gpic(x, 3, GPICConfig(max_iter=30,
                                        straggler_timeout=600.0))
        assert res.health.notes == ()

    def test_supervised_segments_reuse_rng_stream(self, tmp_path):
        """Same seed, different checkpoint cadence: identical results —
        the carry round-trip must not perturb the k-means/start keys."""
        x = _blobs()
        cfg = GPICConfig(max_iter=30, n_vectors=3, embedding="orthogonal",
                         seed=11)
        a = run_gpic(x, 3, cfg.with_(checkpoint_every=3,
                                     ckpt_dir=str(tmp_path / "a")))
        b = run_gpic(x, 3, cfg.with_(checkpoint_every=13,
                                     ckpt_dir=str(tmp_path / "b")))
        _assert_bitwise(a, b, "cadence-invariance")


# ---------------------------------------------------------------------------
# Supervisor config contract
# ---------------------------------------------------------------------------


class TestSupervisorConfig:
    def test_checkpoint_fields_come_as_a_pair(self, tmp_path):
        with pytest.raises(ValueError, match="pair"):
            run_gpic(_blobs(), 3, GPICConfig(checkpoint_every=5))
        with pytest.raises(ValueError, match="pair"):
            run_gpic(_blobs(), 3, GPICConfig(ckpt_dir=str(tmp_path)))

    def test_checkpoint_every_must_be_positive(self, tmp_path):
        with pytest.raises(ValueError, match="checkpoint_every"):
            run_gpic(_blobs(), 3, GPICConfig(checkpoint_every=0,
                                             ckpt_dir=str(tmp_path)))

    def test_straggler_timeout_must_be_positive(self):
        with pytest.raises(ValueError, match="straggler_timeout"):
            run_gpic(_blobs(), 3, GPICConfig(straggler_timeout=0.0))

    def test_ring_fault_needs_mesh_streaming(self):
        with pytest.raises(ValueError, match="ring"):
            run_gpic(_blobs(), 3,
                     GPICConfig(inject_ring_fault=("ring_nan", 0)))

    def test_backoff_and_retries_validated(self):
        with pytest.raises(ValueError, match="max_retries"):
            run_gpic(_blobs(), 3, GPICConfig(max_retries=-1))
        with pytest.raises(ValueError, match="backoff"):
            run_gpic(_blobs(), 3, GPICConfig(backoff=-0.5))


# ---------------------------------------------------------------------------
# Concurrent-fault schedules (local half of the matrix)
# ---------------------------------------------------------------------------


class TestConcurrentFaults:
    def test_transient_failures_recover_clean(self, tmp_path):
        """Only transient faults (injected sweep failures) → the arrays
        come back clean and the outcome is 'recovered', distinct from
        'degraded'."""
        rec = run_schedule(
            _blobs(), 3, FaultSchedule(fail_sweeps=(5, 10)),
            GPICConfig(max_iter=30, eps_scale=1e-7, checkpoint_every=5,
                       ckpt_dir=str(tmp_path / "ck")))
        assert rec["status"] == "recovered", rec
        assert any(n.startswith("resumed:") for n in rec["notes"])
        assert sum(n.startswith("retry:") for n in rec["notes"]) == 2

    def test_multi_fault_run_degrades_not_crashes(self, tmp_path):
        """Isolated rows AND a forced kernel failure AND injected sweep
        failures in ONE run: the supervisor absorbs the transients (retry
        history in notes) and reports the permanent damage as 'degraded' —
        no unclassified crash."""
        from repro.kernels import ops as kops
        kops.reset_kernel_fallbacks()
        jax.clear_caches()
        try:
            rec = run_schedule(
                _blobs(), 3,
                FaultSchedule(isolate_rows=(95,),
                              kernel_failure="degree_normalized_matmat",
                              fail_sweeps=(5,)),
                GPICConfig(affinity=AffinitySpec(kind="rbf", sigma=0.5),
                           max_iter=30, checkpoint_every=5,
                           ckpt_dir=str(tmp_path / "ck")))
        finally:
            kops.reset_kernel_fallbacks()
            jax.clear_caches()
        assert rec["status"] == "degraded", rec
        assert rec["health"]["isolated_rows"] >= 1
        assert any(n.startswith("retry:") for n in rec["notes"])
        assert any(n.startswith("kernel_fallback") for n in rec["notes"])

    def test_fallback_resume_keeps_reference_consistency(self, tmp_path):
        """retry_on_fallback under the supervisor: the tainted segment is
        discarded and the run resumes on the reference oracles from the
        last snapshot — the result matches the all-reference run bitwise
        and the note upgrades to kernel_fallback_resumed."""
        from repro.kernels import ops as kops
        kops.reset_kernel_fallbacks()
        jax.clear_caches()
        x = _blobs()
        cfg = GPICConfig(embedding="orthogonal", n_vectors=2, max_iter=30,
                         retry_on_fallback=True)
        try:
            with kops.forced_kernel_failure("gram"):
                res = run_gpic(x, 3, cfg.with_(
                    checkpoint_every=7, ckpt_dir=str(tmp_path / "ck")))
            ref = run_gpic(x, 3, cfg.with_(use_pallas=False))
            assert any(n.startswith("kernel_fallback_resumed:gram")
                       for n in res.health.notes), res.health.notes
            for name in ("labels", "embeddings", "n_iter_cols"):
                assert np.array_equal(
                    np.asarray(jax.device_get(getattr(res, name))),
                    np.asarray(jax.device_get(getattr(ref, name)))), name
        finally:
            kops.reset_kernel_fallbacks()
            jax.clear_caches()

    def test_apply_feature_faults_composes(self):
        x = apply_feature_faults(
            jnp.zeros((8, 2), jnp.float32),
            FaultSchedule(nan_rows=(1,), isolate_rows=(4,)))
        assert not bool(jnp.isfinite(x[1]).any())
        assert bool((x[4] == 60.0).all())
        assert bool((x[0] == 0.0).all())

    def test_health_to_dict_and_summary(self):
        res = run_gpic(_blobs(), 3, GPICConfig(max_iter=30))
        d = res.health.to_dict()
        assert d["status"] == "ok" and d["bad_columns"] == 0
        s = res.health.summary()
        assert isinstance(s, str) and "status=ok" in s


# ---------------------------------------------------------------------------
# 8-device mesh: resume parity + concurrent faults (slow, subprocess)
# ---------------------------------------------------------------------------

_MESH_PRELUDE = """
    import os, numpy as np
    import jax, jax.numpy as jnp
    from repro.core import AffinitySpec, GPICConfig, run_gpic
    from repro.core.distributed import shard_points
    from repro.core.health import PowerDivergenceError
    from repro.data.synthetic import gaussians
    from repro.train.fault_tolerance import (
        FailureInjector, FaultSchedule, run_schedule)

    mesh = jax.make_mesh((8,), ("data",))

    def fields(res):
        return tuple(np.asarray(jax.device_get(a)) for a in (
            res.labels, res.embeddings, res.n_iter_cols,
            res.converged_cols, res.health.col_status,
            res.health.isolated_rows))

    def check_bitwise(a, b, ctx):
        names = ("labels", "embeddings", "n_iter_cols", "converged_cols",
                 "col_status", "isolated_rows")
        for name, fa, fb in zip(names, fields(a), fields(b)):
            assert np.array_equal(fa, fb), f"{ctx}: {name} differs"
    """


def _mesh(body: str) -> str:
    return run_in_mesh_subprocess(
        textwrap.dedent(_MESH_PRELUDE) + textwrap.dedent(body))


@pytest.mark.slow
def test_mesh_resume_parity_matrix(tmp_path):
    """Interrupt at sweeps {1, mid, last-1} × engines {explicit,
    streaming} × r ∈ {1, 4} on the 8-device mesh: every resumed run is
    bitwise the uninterrupted one (labels, embeddings, n_iter_cols,
    health latches)."""
    out = _mesh(f"""
    root = {str(tmp_path)!r}
    x, _ = gaussians(256, k=3, seed=0)
    xs = shard_points(x, mesh, "data")
    for engine in ("explicit", "streaming"):
        for r in (1, 4):
            cfg = GPICConfig(engine=engine, mesh=mesh, n_vectors=r,
                             embedding="orthogonal" if r > 1 else "pic",
                             max_iter=24)
            base = run_gpic(xs, 3, cfg)
            t_final = int(np.max(np.asarray(base.n_iter_cols)))
            assert t_final > 3, (engine, r, t_final)
            for s in (1, t_final // 2, t_final - 1):
                d = os.path.join(root, f"ck_{{engine}}_{{r}}_{{s}}")
                inj = FailureInjector(fail_at_steps=(s,))
                res = run_gpic(xs, 3,
                               cfg.with_(checkpoint_every=1, ckpt_dir=d),
                               segment_injector=inj.maybe_fail)
                check_bitwise(base, res, f"{{engine}} r={{r}} @{{s}}")
                assert f"resumed:{{s}}" in res.health.notes
                print("OK", engine, r, s)
    """)
    assert out.count("OK") == 12


@pytest.mark.slow
def test_mesh_checkpointed_equals_plain(tmp_path):
    """Undisturbed supervised runs on the mesh (both sharded engines,
    coarse cadence) return the monolithic result bitwise, with no notes."""
    out = _mesh(f"""
    root = {str(tmp_path)!r}
    x, _ = gaussians(256, k=3, seed=0)
    xs = shard_points(x, mesh, "data")
    for engine in ("explicit", "streaming"):
        cfg = GPICConfig(engine=engine, mesh=mesh, n_vectors=2,
                         embedding="ensemble", max_iter=24)
        base = run_gpic(xs, 3, cfg)
        sup = run_gpic(xs, 3, cfg.with_(
            checkpoint_every=7, ckpt_dir=os.path.join(root, engine)))
        check_bitwise(base, sup, engine)
        assert sup.health.notes == ()
        print("OK", engine)
    """)
    assert out.count("OK") == 2


@pytest.mark.slow
def test_mesh_concurrent_fault_matrix(tmp_path):
    """Ring NaN + isolated rows in the SAME sharded streaming run, under
    supervision: each fault class lands on its contracted outcome — the
    ring poison kills every column (typed PowerDivergenceError), while
    isolated rows + transient failures without the ring degrade/recover —
    and nothing escapes as an unclassified crash."""
    out = _mesh(f"""
    root = {str(tmp_path)!r}
    rs = np.random.RandomState(1)
    x = np.concatenate([rs.randn(255, 2).astype(np.float32) * 0.2,
                        np.full((1, 2), 60.0, np.float32)])
    xs = shard_points(x, mesh, "data")
    # the outlier run converges at sweep 6: a fine cadence keeps a live
    # segment boundary (sweep 3) for the injected transient to hit
    cfg = GPICConfig(engine="streaming", mesh=mesh,
                     affinity=AffinitySpec(kind="rbf", sigma=0.5),
                     max_iter=24, checkpoint_every=3)

    # ring NaN + isolated row, one run: total column death is the typed
    # error class; the harness records it instead of crashing
    rec = run_schedule(xs, 2,
                       FaultSchedule(ring_stage=2),
                       cfg.with_(ckpt_dir=os.path.join(root, "ring")))
    assert rec["status"] == "typed_error", rec["status"]
    assert rec["error"] == "PowerDivergenceError", rec
    print("OK ring+isolated typed")

    # same run minus the ring: the isolated row is partial damage —
    # 'degraded', with the injected sweep failure's retry/resume history
    rec = run_schedule(xs, 2,
                       FaultSchedule(fail_sweeps=(3,)),
                       cfg.with_(ckpt_dir=os.path.join(root, "iso")))
    assert rec["status"] == "degraded", rec["status"]
    assert rec["health"]["isolated_rows"] == 1, rec["health"]
    assert any(n.startswith("resumed:") for n in rec["notes"]), rec
    print("OK isolated degraded with resume history")

    # clean data + transient failure only: 'recovered'
    xc, _ = gaussians(256, k=2, seed=3)
    rec = run_schedule(shard_points(xc, mesh, "data"), 2,
                       FaultSchedule(fail_sweeps=(6,)),
                       cfg.with_(affinity=None, affinity_kind="rbf",
                                 sigma=0.3,
                                 ckpt_dir=os.path.join(root, "clean")))
    assert rec["status"] == "recovered", rec["status"]
    print("OK transient recovered")
    """)
    assert out.count("OK") == 3
