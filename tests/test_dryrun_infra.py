"""Tests for the dry-run infrastructure: mesh construction, rules, and the
trip-count-aware HLO analyzer. Multi-device parts run in subprocesses so this
process keeps its 1-device view."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


class TestHloAnalyzer:
    def test_single_matmul_matches_xla(self):
        f = jax.jit(lambda x, w: x @ w)
        s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c = f.lower(s, s).compile()
        mine = analyze(c.as_text()).flops
        ca = c.cost_analysis()
        if isinstance(ca, list):  # older jax wraps per-partition dicts in a list
            ca = ca[0]
        xla = ca["flops"]
        assert mine == pytest.approx(xla, rel=0.01)

    def test_scan_trip_count_scaling(self):
        def scanned(x, ws):
            return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w1 = jax.ShapeDtypeStruct((1, 128, 128), jnp.float32)
        w7 = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
        c1 = jax.jit(scanned).lower(x, w1).compile()
        c7 = jax.jit(scanned).lower(x, w7).compile()
        f1 = analyze(c1.as_text()).flops
        f7 = analyze(c7.as_text()).flops
        assert f7 == pytest.approx(7 * f1, rel=0.05)

    def test_nested_scan_multiplies(self):
        def nested(x, ws):
            def outer(c, w3):
                return jax.lax.scan(lambda cc, w: (cc @ w, None), c, w3)[0], None
            return jax.lax.scan(outer, x, ws)[0]
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)
        c = jax.jit(nested).lower(x, ws).compile()
        a = analyze(c.as_text())
        assert a.flops == pytest.approx(15 * 2 * 64**3, rel=0.05)

    def test_sliced_param_not_overcharged(self):
        """dynamic-slice of a stacked array must charge slice bytes, not the
        full stack (the 88-layer-scan fix)."""
        def f(stack):
            def body(c, i):
                sl = jax.lax.dynamic_slice(stack, (i, 0, 0), (1, 256, 256))
                return c + sl[0], None
            return jax.lax.scan(body, jnp.zeros((256, 256)),
                                jnp.arange(64))[0]
        s = jax.ShapeDtypeStruct((64, 256, 256), jnp.float32)
        c = jax.jit(f).lower(s).compile()
        a = analyze(c.as_text())
        full_stack_every_iter = 64 * 64 * 256 * 256 * 4
        assert a.bytes < full_stack_every_iter / 4, (
            f"bytes {a.bytes:.2e} suggests full-stack charging")


class TestProductionMesh:
    def test_mesh_requires_512_devices(self):
        from repro.launch.mesh import make_production_mesh
        with pytest.raises(RuntimeError, match="512"):
            make_production_mesh(multi_pod=True)

    def test_mesh_shapes_in_subprocess(self):
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
            from repro.launch.mesh import make_production_mesh
            m1 = make_production_mesh()
            m2 = make_production_mesh(multi_pod=True)
            assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
            assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
            print("OK")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = REPO_SRC
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout


class TestRules:
    def test_kv_heads_act_follows_divisibility(self):
        from repro.configs import get_config
        from repro.launch.mesh import build_rules
        rules_granite = build_rules(get_config("granite-34b"))   # kv=1
        assert rules_granite["kv_heads_act"] is None
        rules_stable = build_rules(get_config("stablelm-3b"))    # kv=32
        assert rules_stable["kv_heads_act"] == "model"

    def test_batch_one_idles_data_axis(self):
        from repro.configs import SHAPE_CELLS, get_config
        from repro.launch.mesh import build_rules
        long = next(c for c in SHAPE_CELLS if c.name == "long_500k")
        rules = build_rules(get_config("mamba2-780m"), long)
        assert rules["batch"] is None

    @pytest.mark.slow
    def test_one_dryrun_cell_end_to_end(self, tmp_path):
        env = dict(os.environ)
        env["PYTHONPATH"] = REPO_SRC
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "stablelm-3b", "--shape", "decode_32k",
             "--mesh", "single", "--out", str(tmp_path)],
            capture_output=True, text=True, env=env, timeout=580)
        assert out.returncode == 0, out.stderr[-3000:]
        assert "all requested cells compiled" in out.stdout
