"""Hypothesis property tests on the PIC/GPIC system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    affinity_matrix,
    as_operator,
    gpic,
    gpic_matrix_free,
    orthonormalize_block,
    pic_from_affinity,
    row_normalize_features,
)
from repro.core.affinity import degree_matrix_free, matvec_matrix_free
from repro.core.kmeans import kmeans


def _points(n, m, seed):
    return jax.random.normal(jax.random.key(seed), (n, m)) * 2.0


class TestAlgebraicInvariants:
    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(8, 120), m=st.integers(1, 8), seed=st.integers(0, 99))
    def test_w_is_row_stochastic(self, n, m, seed):
        """W = D^-1 A must have unit row sums (the paper's normalization)."""
        x = _points(n, m, seed)
        a = affinity_matrix(x, "cosine_shifted")
        d = jnp.sum(a, axis=1)
        w = a / jnp.maximum(d, 1e-30)[:, None]
        np.testing.assert_allclose(np.asarray(jnp.sum(w, axis=1)), 1.0,
                                   atol=1e-4)

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(8, 120), seed=st.integers(0, 99))
    def test_embedding_l1_is_one(self, n, seed):
        """Every power iterate is L1-normalized (Algorithm 2 line 10)."""
        x = _points(n, 2, seed)
        res = gpic(x, 2, key=jax.random.key(0), affinity_kind="cosine_shifted",
                   max_iter=7, use_pallas=False)
        assert abs(float(jnp.sum(jnp.abs(res.embedding))) - 1.0) < 1e-4

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(8, 150), m=st.integers(1, 8), seed=st.integers(0, 99))
    def test_matrix_free_equals_explicit_matvec(self, n, m, seed):
        """O2's factored A·v must equal the dense product for random v."""
        x = _points(n, m, seed)
        xn = row_normalize_features(x)
        a = affinity_matrix(x, "cosine_shifted")
        v = jax.random.uniform(jax.random.key(seed + 1), (n,))
        np.testing.assert_allclose(
            np.asarray(a @ v),
            np.asarray(matvec_matrix_free(xn, v, "cosine_shifted")),
            atol=5e-4, rtol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(16, 100), seed=st.integers(0, 50))
    def test_labels_in_range_and_all_assigned(self, n, seed):
        x = _points(n, 2, seed)
        k = 3
        res = gpic_matrix_free(x, k, key=jax.random.key(1), max_iter=10)
        labels = np.asarray(res.labels)
        assert labels.shape == (n,)
        assert labels.min() >= 0 and labels.max() < k

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(10, 80), k=st.integers(2, 5), seed=st.integers(0, 50))
    def test_kmeans_centroids_finite_and_labels_valid(self, n, k, seed):
        x = _points(n, 3, seed)
        labels, cents = kmeans(jax.random.key(seed), x, k, iters=10)
        assert np.isfinite(np.asarray(cents)).all()
        assert int(labels.max()) < k

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(8, 100), seed=st.integers(0, 50))
    def test_degree_positive(self, n, seed):
        """Shifted-cosine degrees are strictly positive (W well-defined)."""
        x = _points(n, 2, seed)
        xn = row_normalize_features(x)
        d = degree_matrix_free(xn, "cosine_shifted")
        assert float(jnp.min(d)) > 0.0


class TestBlockOrthogonalization:
    """Properties of the orthogonal embedding mode (DESIGN.md §10)."""

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(16, 300), r=st.integers(2, 8), seed=st.integers(0, 99))
    def test_qr_step_leaves_block_orthonormal(self, n, r, seed):
        """After the pinned Cholesky-QR, [v0/||v0||_2, cols 1..r-1] must be
        orthonormal to 1e-5 — column 0 is only ever un-normalized, never
        un-orthogonal."""
        v = jax.random.uniform(jax.random.key(seed), (n, r)) + 0.05
        v = v / jnp.sum(jnp.abs(v), axis=0, keepdims=True)   # engine scale
        out = orthonormalize_block(as_operator(lambda x: x), v)
        np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                      np.asarray(v[:, 0]))   # pinned bitwise
        q0 = out[:, :1] / jnp.linalg.norm(out[:, 0])
        q = jnp.concatenate([q0, out[:, 1:]], axis=1)
        np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(r), atol=1e-5)

    # n from a small menu: every distinct n recompiles both jitted
    # pipelines, and the property lives in the loop logic, not the shape
    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from((24, 64, 101, 160)), seed=st.integers(0, 50))
    def test_orthogonal_r1_is_bitwise_classic(self, n, seed):
        """embedding='orthogonal' with r=1 IS the classic PIC loop — same
        floats, same iteration counts, not merely close."""
        x = _points(n, 2, seed)
        kw = dict(key=jax.random.key(0), affinity_kind="cosine_shifted",
                  max_iter=30, use_pallas=False)
        rp = gpic(x, 2, embedding="pic", **kw)
        ro = gpic(x, 2, embedding="orthogonal", **kw)
        np.testing.assert_array_equal(np.asarray(rp.embeddings),
                                      np.asarray(ro.embeddings))
        assert int(rp.n_iter) == int(ro.n_iter)
        assert bool(rp.converged) == bool(ro.converged)

    @settings(max_examples=8, deadline=None)
    @given(n=st.sampled_from((32, 96, 150)), seed=st.integers(0, 30))
    def test_orthogonal_pins_column0_to_classic_trajectory(self, n, seed):
        """Deflation pinning: with r > 1 the block's column 0 still follows
        the classic degree-seeded trajectory bitwise (the QR never touches
        it, the sweep is column-independent, and its freeze rule is the
        classic one)."""
        x = _points(n, 2, seed)
        kw = dict(key=jax.random.key(1), affinity_kind="cosine_shifted",
                  max_iter=40, use_pallas=False, n_vectors=4)
        rp = gpic(x, 3, embedding="pic", **kw)
        ro = gpic(x, 3, embedding="orthogonal", **kw)
        np.testing.assert_array_equal(np.asarray(rp.embedding),
                                      np.asarray(ro.embedding))
        assert int(rp.n_iter) == int(ro.n_iter)


class TestScaleInvariance:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(16, 80), seed=st.integers(0, 30),
           scale=st.floats(0.1, 10.0))
    def test_cosine_affinity_scale_invariant(self, n, seed, scale):
        """Cosine affinity ignores point magnitudes -> identical clustering."""
        x = _points(n, 2, seed)
        a1 = affinity_matrix(x, "cosine_shifted")
        a2 = affinity_matrix(x * scale, "cosine_shifted")
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(16, 64), seed=st.integers(0, 30))
    def test_permutation_equivariance_of_embedding(self, n, seed):
        """Permuting inputs permutes the PIC embedding identically."""
        x = _points(n, 2, seed)
        perm = np.random.default_rng(seed).permutation(n)
        a1 = affinity_matrix(x, "cosine_shifted")
        a2 = affinity_matrix(x[perm], "cosine_shifted")
        r1 = pic_from_affinity(a1, 2, key=jax.random.key(0), max_iter=6)
        r2 = pic_from_affinity(a2, 2, key=jax.random.key(0), max_iter=6)
        np.testing.assert_allclose(np.asarray(r1.embedding)[perm],
                                   np.asarray(r2.embedding), atol=1e-5)
