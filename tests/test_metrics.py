"""Property-based + unit tests for cluster validation metrics (Experiment II)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import adjusted_rand_index, jaccard_index, purity, rand_index


def _random_labels(draw, n, k):
    return draw(st.lists(st.integers(0, k - 1), min_size=n, max_size=n))


class TestExactValues:
    def test_identical_partitions(self):
        y = [0, 0, 1, 1, 2, 2]
        assert adjusted_rand_index(y, y) == pytest.approx(1.0)
        assert jaccard_index(y, y) == pytest.approx(1.0)
        assert rand_index(y, y) == pytest.approx(1.0)
        assert purity(y, y) == pytest.approx(1.0)

    def test_label_permutation_invariant(self):
        y = [0, 0, 1, 1, 2, 2]
        z = [2, 2, 0, 0, 1, 1]  # same partition, renamed
        assert adjusted_rand_index(y, z) == pytest.approx(1.0)
        assert jaccard_index(y, z) == pytest.approx(1.0)

    def test_known_ari_value(self):
        # sklearn-documented example: ARI([0,0,1,1],[0,0,1,2]) = 0.5714...
        ari = adjusted_rand_index([0, 0, 1, 1], [0, 0, 1, 2])
        assert ari == pytest.approx(0.5714285714, abs=1e-9)

    def test_single_cluster_vs_all_distinct(self):
        y = [0] * 10
        z = list(range(10))
        assert jaccard_index(y, z) == pytest.approx(0.0)


class TestMetricProperties:
    @settings(max_examples=200, deadline=None)
    @given(st.data())
    def test_ari_symmetric(self, data):
        n = data.draw(st.integers(2, 40))
        a = data.draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
        b = data.draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
        assert adjusted_rand_index(a, b) == pytest.approx(
            adjusted_rand_index(b, a), abs=1e-12
        )

    @settings(max_examples=200, deadline=None)
    @given(st.data())
    def test_jaccard_bounds(self, data):
        n = data.draw(st.integers(2, 40))
        a = data.draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
        b = data.draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
        j = jaccard_index(a, b)
        assert 0.0 <= j <= 1.0

    @settings(max_examples=200, deadline=None)
    @given(st.data())
    def test_ari_upper_bound(self, data):
        n = data.draw(st.integers(2, 40))
        a = data.draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
        b = data.draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
        assert adjusted_rand_index(a, b) <= 1.0 + 1e-12

    @settings(max_examples=200, deadline=None)
    @given(st.data())
    def test_purity_bounds(self, data):
        n = data.draw(st.integers(2, 40))
        a = data.draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
        b = data.draw(st.lists(st.integers(0, 4), min_size=n, max_size=n))
        assert 0.0 < purity(a, b) <= 1.0 + 1e-12

    @settings(max_examples=200, deadline=None)
    @given(st.data())
    def test_pair_counts_consistency(self, data):
        from repro.core.metrics import pair_confusion

        n = data.draw(st.integers(2, 30))
        a = data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n))
        b = data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n))
        pa, pb, pc, pd = pair_confusion(a, b)
        assert pa + pb + pc + pd == pytest.approx(n * (n - 1) / 2)
