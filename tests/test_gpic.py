"""Integration tests: the accelerated GPIC pipeline vs the reference PIC.

Validates the paper's exactness claim — "This GPU implemented PIC method
converges to exactly the same result of the original serial method" — for
both the fused-Pallas-kernel path and the matrix-free path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    adjusted_rand_index,
    gpic,
    gpic_matrix_free,
    pic_reference,
)
from repro.data import gaussians, shapes, three_circles


class TestGPICExactness:
    @pytest.mark.parametrize("kind,sigma", [("rbf", 0.3), ("cosine_shifted", 1.0)])
    def test_gpic_matches_reference_embedding(self, kind, sigma):
        x, _ = gaussians(300, seed=0)
        x = jnp.asarray(x)
        ref = pic_reference(x, 4, key=jax.random.key(0), affinity_kind=kind,
                            sigma=sigma, max_iter=100)
        acc = gpic(x, 4, key=jax.random.key(0), affinity_kind=kind,
                   sigma=sigma, max_iter=100)
        assert int(ref.n_iter) == int(acc.n_iter)
        np.testing.assert_allclose(ref.embedding, acc.embedding,
                                   atol=1e-7, rtol=1e-5)

    def test_gpic_matches_reference_labels(self):
        x, y = three_circles(400, seed=0)
        x = jnp.asarray(x)
        ref = pic_reference(x, 3, key=jax.random.key(1), affinity_kind="rbf",
                            sigma=0.3, max_iter=300)
        acc = gpic(x, 3, key=jax.random.key(1), affinity_kind="rbf",
                   sigma=0.3, max_iter=300)
        ari = adjusted_rand_index(np.asarray(ref.labels), np.asarray(acc.labels))
        assert ari == pytest.approx(1.0)

    def test_matrix_free_matches_explicit(self):
        """O2 must be *exactly* the same math as the explicit pipeline."""
        x, _ = gaussians(256, seed=1)
        x = jnp.asarray(x)
        exp = gpic(x, 4, key=jax.random.key(2), affinity_kind="cosine_shifted",
                   max_iter=100)
        mf = gpic_matrix_free(x, 4, key=jax.random.key(2),
                              affinity_kind="cosine_shifted", max_iter=100)
        assert int(exp.n_iter) == int(mf.n_iter)
        np.testing.assert_allclose(exp.embedding, mf.embedding,
                                   atol=1e-6, rtol=1e-4)

    def test_gpic_quality(self):
        x, y = shapes(480, seed=0)
        res = gpic(jnp.asarray(x), 4, key=jax.random.key(1),
                   affinity_kind="rbf", sigma=0.3, max_iter=400)
        assert adjusted_rand_index(y, np.asarray(res.labels)) >= 0.9

    def test_matrix_free_scales_to_large_n(self):
        """n = 20k would need a 1.6 GB A matrix; matrix-free runs it easily."""
        x, y = gaussians(20_000, seed=0)
        res = gpic_matrix_free(jnp.asarray(x), 4, key=jax.random.key(0),
                               affinity_kind="cosine_shifted", max_iter=30)
        assert res.labels.shape == (20_000,)
        assert np.isfinite(np.asarray(res.embedding)).all()

    def test_unconverged_flag_when_max_iter_hits(self):
        x, _ = three_circles(300, seed=0)
        res = gpic(jnp.asarray(x), 3, key=jax.random.key(0),
                   affinity_kind="rbf", sigma=0.3, max_iter=2)
        assert not bool(res.converged)
        assert int(res.n_iter) == 2
