"""Tests for the multi-vector power engine (ISSUE 1).

Covers: the batched degree-normalized mat-mat kernel vs vmapped matvec, the
streaming (A-free) kernel vs the explicit-A path for all affinity kinds and
non-divisible n, the lcm tile-padding regression, the interpret-probe env
override, the tile autotuner, bf16 A storage, and the engine-level
guarantees (frozen-column parity, streaming == explicit clustering).
"""
import importlib
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import gpic, gpic_matrix_free, matmat_matrix_free, pic_from_affinity
from repro.core.affinity import affinity_matrix, row_normalize_features
from repro.core.power import batched_power_iteration, init_power_vectors
from repro.kernels import ops, ref
from repro.kernels.tuning import choose_tiles, round_up_to_lcm

KINDS = ["cosine", "cosine_shifted", "rbf"]


def _problem(n, m, seed, kind):
    x = jax.random.normal(jax.random.key(seed), (n, m))
    return x if kind == "rbf" else row_normalize_features(x)


class TestDegreeNormalizedMatmat:
    @pytest.mark.parametrize("n", [64, 129, 300, 517])
    @pytest.mark.parametrize("r", [1, 2, 3, 4])
    def test_matches_vmapped_matvec(self, n, r):
        inp = _problem(n, 3, n + r, "cosine_shifted")
        a, d = ref.affinity_and_degree_ref(inp, kind="cosine_shifted")
        v = jax.random.uniform(jax.random.key(r), (n, r))
        batched = ops.degree_normalized_matmat(a, v, d)
        vmapped = jax.vmap(
            lambda col: ops.degree_normalized_matvec(a, col, d),
            in_axes=1, out_axes=1,
        )(v)
        np.testing.assert_allclose(batched, vmapped, atol=1e-5, rtol=1e-5)

    def test_r1_equals_matvec_exactly(self):
        inp = _problem(200, 2, 0, "cosine_shifted")
        a, d = ref.affinity_and_degree_ref(inp, kind="cosine_shifted")
        v = jax.random.uniform(jax.random.key(1), (200,))
        np.testing.assert_array_equal(
            ops.degree_normalized_matmat(a, v[:, None], d)[:, 0],
            ops.degree_normalized_matvec(a, v, d),
        )

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(16, 384), r=st.integers(1, 4))
    def test_matches_reference_property(self, n, r):
        inp = _problem(n, 2, n * 7 + r, "cosine_shifted")
        a, d = ref.affinity_and_degree_ref(inp, kind="cosine_shifted")
        v = jax.random.uniform(jax.random.key(n + r), (n, r))
        np.testing.assert_allclose(
            ops.degree_normalized_matmat(a, v, d),
            ref.degree_normalized_matmat_ref(a, v, d),
            atol=1e-5, rtol=1e-5,
        )

    def test_bf16_storage_f32_accumulation(self):
        inp = _problem(300, 4, 2, "cosine_shifted")
        a, d = ops.affinity_and_degree(inp, kind="cosine_shifted",
                                       out_dtype=jnp.bfloat16)
        assert a.dtype == jnp.bfloat16
        v = jax.random.uniform(jax.random.key(3), (300, 2))
        u16 = ops.degree_normalized_matmat(a, v, d)
        assert u16.dtype == jnp.float32
        a32, d32 = ops.affinity_and_degree(inp, kind="cosine_shifted")
        u32 = ops.degree_normalized_matmat(a32, v, d32)
        np.testing.assert_allclose(u16, u32, atol=2e-2, rtol=2e-2)


class TestStreamingKernel:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("n", [129, 300])
    @pytest.mark.parametrize("r", [1, 2, 3, 4])
    def test_matches_explicit_path(self, kind, n, r):
        """The A-free kernel must reproduce build-A-then-multiply."""
        inp = _problem(n, 3, n, kind)
        a, d = ref.affinity_and_degree_ref(inp, kind=kind, sigma=0.8)
        v = jax.random.uniform(jax.random.key(n + r), (n, r))
        streamed = ops.streaming_matmat(inp, v, d, kind=kind, sigma=0.8)
        explicit = ref.degree_normalized_matmat_ref(a, v, d)
        # raw-cosine degrees can be ~0, so (A V)/d amplifies magnitudes
        # enormously; relative tolerance is the meaningful check there
        np.testing.assert_allclose(streamed, explicit, atol=1e-4, rtol=2e-3)

    @pytest.mark.parametrize("kind", KINDS)
    def test_degree_matches_fused_affinity_kernel(self, kind):
        """Streamed degrees equal the affinity kernel's fused RowSum (the
        reduction orders are matched for bitwise engine parity)."""
        inp = _problem(300, 5, 9, kind)
        _, d_explicit = ops.affinity_and_degree(inp, kind=kind, sigma=0.8,
                                                tm=128, tn=128)
        d_streamed = ops.streaming_degree(inp, kind=kind, sigma=0.8,
                                          tm=128, tn=128)
        np.testing.assert_array_equal(d_streamed, d_explicit)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(16, 300), r=st.integers(1, 4),
           kind=st.sampled_from(KINDS))
    def test_streaming_property(self, n, r, kind):
        inp = _problem(n, 2, n * 3 + r, kind)
        v = jax.random.uniform(jax.random.key(n), (n, r))
        np.testing.assert_allclose(
            ops.streaming_matmat(inp, v, None, kind=kind, sigma=1.1),
            ref.affinity_matmat_ref(inp, v, None, kind=kind, sigma=1.1),
            atol=1e-4, rtol=1e-4,
        )


class TestLcmPadding:
    """Regression: n_pad must round to lcm(tm, tn), not max(tm, tn) —
    max() breaks whenever tm/tn are not mutually divisible."""

    @pytest.mark.parametrize("tm,tn", [(256, 160), (256, 192), (128, 96)])
    def test_matmat_non_divisible_tiles(self, tm, tn):
        n = 300
        inp = _problem(n, 3, 1, "cosine_shifted")
        a, d = ref.affinity_and_degree_ref(inp, kind="cosine_shifted")
        v = jax.random.uniform(jax.random.key(2), (n, 2))
        np.testing.assert_allclose(
            ops.degree_normalized_matmat(a, v, d, tm=tm, tn=tn),
            ref.degree_normalized_matmat_ref(a, v, d),
            atol=1e-5, rtol=1e-5,
        )

    @pytest.mark.parametrize("tm,tn", [(256, 160), (128, 96)])
    def test_affinity_non_divisible_tiles(self, tm, tn):
        inp = _problem(300, 3, 4, "cosine_shifted")
        a_k, d_k = ops.affinity_and_degree(inp, kind="cosine_shifted",
                                           tm=tm, tn=tn)
        a_r, d_r = ref.affinity_and_degree_ref(inp, kind="cosine_shifted")
        np.testing.assert_allclose(a_k, a_r, atol=1e-5)
        np.testing.assert_allclose(d_k, d_r, atol=1e-3, rtol=1e-5)

    def test_round_up_to_lcm(self):
        assert round_up_to_lcm(300, 256, 256) == 512
        assert round_up_to_lcm(300, 256, 160) == 1280
        assert round_up_to_lcm(1280, 256, 160) == 1280


class TestInterpretProbe:
    def test_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
        assert ops._probe_interpret() is True
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "compiled")
        assert ops._probe_interpret() is False
        monkeypatch.delenv("REPRO_FORCE_INTERPRET")
        assert ops._probe_interpret() == (jax.default_backend() != "tpu")

    def test_probe_cached_at_import(self):
        # the module-level constant is what every op consults — no
        # per-call backend probing
        assert isinstance(ops._INTERPRET, bool)
        assert ops._interpret() is ops._INTERPRET


class TestTileAutotuner:
    def test_small_problem_gets_small_tiles(self):
        # 100 -> padding to 256 would be >60% phantom rows; 128 wastes 28
        tm, tn = choose_tiles(100)
        assert (tm, tn) == (128, 128)

    def test_large_problem_gets_large_tiles(self):
        tm, tn = choose_tiles(8192)
        assert tm >= 256 and tn >= 256

    def test_fits_and_divides(self):
        for n in (100, 300, 1024, 5000):
            tm, tn = choose_tiles(n, r=4, m=64)
            n_pad = round_up_to_lcm(n, tm, tn)
            assert n_pad % tm == 0 and n_pad % tn == 0

    def test_default_tiles_used_by_ops(self):
        # ops must accept tm=tn=None and autotune (no crash, right result)
        inp = _problem(150, 2, 5, "cosine_shifted")
        a, d = ops.affinity_and_degree(inp, kind="cosine_shifted",
                                       tm=None, tn=None)
        a_r, d_r = ref.affinity_and_degree_ref(inp, kind="cosine_shifted")
        np.testing.assert_allclose(a, a_r, atol=1e-5)


class TestDispatchRegistry:
    def test_modes_registered(self):
        assert set(ops.modes_for("degree_normalized_matmat")) == {
            "pallas", "reference"}
        assert set(ops.modes_for("streaming_matmat")) == {
            "streaming", "reference"}

    def test_unknown_mode_raises_with_choices(self):
        with pytest.raises(ValueError, match="available"):
            ops.dispatch("degree_normalized_matmat", "nope")


class TestEngine:
    def test_frozen_columns_reproduce_solo_loops_exactly(self):
        """The batched loop with per-column freezing must give every column
        the EXACT trajectory of a dedicated single-vector loop. Tested with
        a columnwise-identical matmat so the only variable is the loop
        logic itself (core/power.py owns exactly that)."""
        x = jax.random.normal(jax.random.key(0), (128, 2))
        a = affinity_matrix(x, "cosine_shifted")
        d = jnp.sum(a, axis=1)
        w = a / jnp.maximum(d, 1e-30)[:, None]

        def mm(vv):  # per-column products: r cannot change the float ops
            return jnp.stack([w @ vv[:, c] for c in range(vv.shape[1])],
                             axis=1)

        v0 = init_power_vectors(jax.random.key(1), d, 3)
        v_b, t_b, done_b = batched_power_iteration(mm, v0, 1e-5 / 128, 60)
        for c in range(3):
            v_s, t_s, done_s = batched_power_iteration(
                mm, v0[:, c:c + 1], 1e-5 / 128, 60)
            # values agree to XLA fusion noise (~2 ulp at 1/n magnitude);
            # the loop SEMANTICS — per-column counts and flags — are exact
            np.testing.assert_allclose(v_b[:, c], v_s[:, 0], atol=1e-8,
                                       rtol=0)
            assert int(t_b[c]) == int(t_s[0])
            assert bool(done_b[c]) == bool(done_s[0])

    def test_primary_column_independent_of_r(self):
        """Adding random extra vectors must not perturb the paper's primary
        (degree-start) trajectory beyond dot-reduction float noise."""
        x = jnp.asarray(jax.random.normal(jax.random.key(0), (256, 2)))
        r1 = gpic(x, 3, key=jax.random.key(1), max_iter=40)
        r4 = gpic(x, 3, key=jax.random.key(1), max_iter=40, n_vectors=4)
        np.testing.assert_allclose(r1.embedding, r4.embedding, atol=1e-6)

    @pytest.mark.parametrize("kind,sigma", [("cosine_shifted", 1.0),
                                            ("rbf", 0.4)])
    def test_streaming_engine_clusters_identically(self, kind, sigma):
        x = jnp.asarray(jax.random.normal(jax.random.key(2), (300, 2)))
        e = gpic(x, 3, key=jax.random.key(3), affinity_kind=kind, sigma=sigma,
                 max_iter=50, engine="explicit")
        s = gpic(x, 3, key=jax.random.key(3), affinity_kind=kind, sigma=sigma,
                 max_iter=50, engine="streaming")
        np.testing.assert_array_equal(np.asarray(e.labels),
                                      np.asarray(s.labels))
        np.testing.assert_array_equal(np.asarray(e.embedding),
                                      np.asarray(s.embedding))

    def test_unknown_engine_raises(self):
        x = jnp.ones((64, 2))
        with pytest.raises(ValueError, match="engine"):
            gpic(x, 2, key=jax.random.key(0), engine="warp")

    def test_matrix_free_multivector_batched(self):
        x = jnp.asarray(jax.random.normal(jax.random.key(4), (200, 3)))
        res = gpic_matrix_free(x, 3, key=jax.random.key(5), max_iter=30,
                               n_vectors=3)
        assert res.labels.shape == (200,)
        assert np.isfinite(np.asarray(res.embedding)).all()

    def test_pic_from_affinity_multivector(self):
        x = jax.random.normal(jax.random.key(6), (150, 2))
        a = affinity_matrix(x, "cosine_shifted")
        res = pic_from_affinity(a, 3, key=jax.random.key(7), max_iter=30,
                                n_vectors=3)
        assert res.labels.shape == (150,)

    def test_batched_iteration_counts_per_column(self):
        """Columns converge independently; t_cols tracks each one."""
        x = jax.random.normal(jax.random.key(8), (128, 2))
        a = affinity_matrix(x, "cosine_shifted")
        d = jnp.sum(a, axis=1)
        w = a / jnp.maximum(d, 1e-30)[:, None]
        v0 = init_power_vectors(jax.random.key(9), d, 3)
        v, t_cols, done = batched_power_iteration(
            lambda vv: w @ vv, v0, 1e-5 / 128, 100)
        assert v.shape == (128, 3)
        assert t_cols.shape == (3,) and done.shape == (3,)
        assert (np.asarray(t_cols) >= 1).all()

    def test_matmat_matrix_free_batched_matches_loop(self):
        xn = row_normalize_features(
            jax.random.normal(jax.random.key(10), (120, 4)))
        v = jax.random.uniform(jax.random.key(11), (120, 3))
        batched = matmat_matrix_free(xn, v, "cosine_shifted")
        for c in range(3):
            np.testing.assert_allclose(
                batched[:, c],
                matmat_matrix_free(xn, v[:, c], "cosine_shifted"),
                atol=1e-5, rtol=1e-5,
            )
