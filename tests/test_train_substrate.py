"""Tests for optimizer, checkpointing, fault tolerance and compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_smoke_config
from repro.models import get_api, make_train_batch
from repro.train import adamw_init, build_train_step, lr_schedule
from repro.train import checkpoint as ckpt
from repro.train.compression import (
    ErrorFeedback,
    compress_decompress,
    dequantize_int8,
    ef_compress,
    quantize_int8,
)
from repro.train.fault_tolerance import (
    FailureInjector,
    RestartableLoop,
    SimulatedFailure,
    StragglerMonitor,
)

TCFG = TrainConfig(compute_dtype="float32", remat="none",
                   learning_rate=1e-3, warmup_steps=2, total_steps=100)


class TestOptimizer:
    def test_lr_schedule_warmup_and_decay(self):
        cfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(lr_schedule(jnp.int32(s), cfg)) for s in
               [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(5e-4)
        assert lrs[2] == pytest.approx(1e-3)
        assert lrs[3] < lrs[2]
        assert lrs[4] == pytest.approx(1e-4, rel=1e-3)  # 10% floor

    def test_adamw_reduces_loss_on_quadratic(self):
        from repro.train.optimizer import adamw_update
        cfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=1000,
                          weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(params, g, state, cfg)
        assert float(loss(params)) < 1e-2


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": {"c": jnp.ones((2,), jnp.bfloat16),
                      "d": jnp.int32(7)}}
        path = str(tmp_path / "step_000001")
        ckpt.save(path, tree, step=1)
        restored, step = ckpt.restore(path, jax.tree.map(lambda x: x, tree))
        assert step == 1
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_overwrite(self, tmp_path):
        path = str(tmp_path / "step_000002")
        ckpt.save(path, {"x": jnp.zeros(3)}, step=2)
        ckpt.save(path, {"x": jnp.ones(3)}, step=2)
        restored, _ = ckpt.restore(path, {"x": jnp.zeros(3)})
        np.testing.assert_array_equal(np.asarray(restored["x"]), 1.0)

    def test_async_checkpointer(self, tmp_path):
        saver = ckpt.AsyncCheckpointer()
        path = str(tmp_path / "step_000003")
        saver.save_async(path, {"x": jnp.full((4,), 3.0)}, step=3)
        saver.wait()
        restored, step = ckpt.restore(path, {"x": jnp.zeros(4)})
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["x"]), 3.0)

    def test_latest_step(self, tmp_path):
        for s in (1, 5, 3):
            ckpt.save(str(tmp_path / f"step_{s:06d}"), {"x": jnp.zeros(1)},
                      step=s)
        assert ckpt.latest_step(str(tmp_path)).endswith("step_000005")


class TestFaultTolerance:
    def _make_loop(self, tmp_path, injector=None, ckpt_every=3):
        cfg = get_smoke_config("stablelm-3b")
        api = get_api(cfg)
        params = api.init_params(jax.random.key(0), cfg)
        opt = adamw_init(params)
        tstep = jax.jit(build_train_step(cfg, TCFG))

        def step_fn(state, batch):
            p, o = state
            p, o, m = tstep(p, o, batch)
            return (p, o), m

        def data_fn(step):
            return make_train_batch(cfg, 2, 16, 1000 + step)

        loop = RestartableLoop(step_fn, data_fn, str(tmp_path),
                               ckpt_every=ckpt_every, injector=injector,
                               async_save=False)
        return loop, (params, opt)

    def test_restart_is_bit_exact(self, tmp_path):
        """A crash + restore must reproduce the uninterrupted run exactly."""
        loop_a, state0 = self._make_loop(tmp_path / "a")
        final_a, step_a, _ = loop_a.run(state0, 10)

        inj = FailureInjector(fail_at_steps=[7])
        loop_b, state0b = self._make_loop(tmp_path / "b", injector=inj)
        final_b, step_b, _ = loop_b.run(state0b, 10)

        assert step_a == step_b == 10
        assert loop_b.restarts == 1
        for a, b in zip(jax.tree.leaves(final_a), jax.tree.leaves(final_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_multiple_failures(self, tmp_path):
        inj = FailureInjector(fail_at_steps=[2, 5, 8])
        loop, state0 = self._make_loop(tmp_path, injector=inj)
        _, step, _ = loop.run(state0, 10)
        assert step == 10
        assert loop.restarts == 3

    def test_straggler_monitor(self):
        mon = StragglerMonitor(threshold=2.0)
        for i in range(10):
            assert not mon.record(i, 0.1)
        assert mon.record(10, 0.5)          # 5x median -> flagged
        assert len(mon.flagged) == 1


class TestFaultTolerancePrimitives:
    """Direct unit tests of the fault-tolerance primitives on trivial
    state, independent of the model train step (the robustness suite
    drives the same pieces through ClusteringFaultHarness)."""

    @staticmethod
    def _loop(tmp_path, injector=None, ckpt_every=2):
        # state is one scalar; step t adds data_fn(t) — a pure, replayable
        # step whose exact final value is the sum of the batch sequence
        def step_fn(state, batch):
            s = state["s"] + batch
            return {"s": s}, {"s": float(s)}

        def data_fn(step):
            return jnp.float32(step + 1)

        return RestartableLoop(step_fn, data_fn, str(tmp_path),
                               ckpt_every=ckpt_every, injector=injector,
                               async_save=False)

    def test_save_restore_roundtrip(self, tmp_path):
        loop = self._loop(tmp_path)
        state = {"s": jnp.float32(41.5)}
        loop._save(state, 7)
        restored, step = loop._restore({"s": jnp.float32(0.0)})
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["s"]),
                                      np.asarray(state["s"]))

    def test_restore_without_checkpoint_is_none(self, tmp_path):
        assert self._loop(tmp_path / "empty")._restore(
            {"s": jnp.float32(0.0)}) is None

    def test_restore_picks_latest_step(self, tmp_path):
        loop = self._loop(tmp_path)
        for step in (2, 10, 6):
            loop._save({"s": jnp.float32(step)}, step)
        _, step = loop._restore({"s": jnp.float32(0.0)})
        assert step == 10

    def test_crash_replay_is_exact(self, tmp_path):
        # sum(1..8) = 36 regardless of a crash at step 5 (between saves)
        inj = FailureInjector(fail_at_steps=[5])
        loop = self._loop(tmp_path, injector=inj)
        state, step, log = loop.run({"s": jnp.float32(0.0)}, 8)
        assert step == 8 and loop.restarts == 1
        assert float(state["s"]) == 36.0
        # the replayed steps appear twice in the metrics log; the final
        # values per step are the uninterrupted ones
        assert log[-1]["s"] == 36.0

    def test_injector_fires_once_per_step(self):
        inj = FailureInjector(fail_at_steps=[3])
        with pytest.raises(SimulatedFailure):
            inj.maybe_fail(3)
        inj.maybe_fail(3)   # second visit passes (fire-once accounting)
        inj.maybe_fail(4)
        assert inj.fired == {3}

    def test_straggler_needs_warmup_samples(self):
        # fewer than 5 samples never flags, however slow the step
        mon = StragglerMonitor(threshold=2.0)
        for i in range(4):
            assert not mon.record(i, 10.0 if i == 3 else 0.1)
        assert mon.flagged == []

    def test_straggler_threshold_boundary(self):
        mon = StragglerMonitor(threshold=2.0)
        for i in range(8):
            mon.record(i, 1.0)
        assert not mon.record(8, 2.0)   # == threshold*median: not flagged
        assert mon.record(9, 2.0001)    # just above: flagged
        assert mon.flagged[-1][0] == 9

    def test_straggler_window_eviction(self):
        mon = StragglerMonitor(threshold=2.0, window=5)
        for i in range(5):
            mon.record(i, 1.0)
        for i in range(5, 10):
            mon.record(i, 100.0)        # first flags, then shifts the median
        assert mon.median == 100.0
        assert not mon.record(10, 150.0)  # 1.5x new median: healthy again


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.key(0), (64, 128))
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(jnp.max(err)) <= float(jnp.max(s)) * 0.51

    def test_compress_preserves_structure(self):
        g = {"a": jnp.ones((4, 4)), "b": {"c": jnp.zeros((3,))}}
        dq, err = compress_decompress(g)
        assert jax.tree_util.tree_structure(dq) == jax.tree_util.tree_structure(g)

    def test_error_feedback_converges(self):
        """EF-SGD on a quadratic: with feedback the bias vanishes; without,
        aggressive quantization stalls progress sooner."""
        w = jnp.array([1.0, -2.0, 3.0, -4.0])
        target = jnp.zeros(4)

        def grad(w):
            return 2 * (w - target)

        # with error feedback
        w_ef = w
        ef = ErrorFeedback.init({"w": w})
        for _ in range(300):
            g = {"w": grad(w_ef)}
            dq, ef = ef_compress(g, ef)
            w_ef = w_ef - 0.05 * dq["w"]
        assert float(jnp.max(jnp.abs(w_ef))) < 1e-2

    def test_train_step_with_compression_runs(self):
        cfg = get_smoke_config("stablelm-3b")
        api = get_api(cfg)
        params = api.init_params(jax.random.key(0), cfg)
        opt = adamw_init(params)
        tcfg = TrainConfig(**{**TCFG.__dict__, "gradient_compression": True})
        step = jax.jit(build_train_step(cfg, tcfg))
        batch = make_train_batch(cfg, 2, 16, 0)
        _, _, m = step(params, opt, batch)
        assert np.isfinite(float(m["loss"]))


class TestCheckpointIntegrity:
    """Per-leaf CRC32 + typed CheckpointCorruptError (PR 9)."""

    def _tree(self):
        return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": jnp.ones((4,), jnp.float32)}

    def test_manifest_records_crc32(self, tmp_path):
        import json

        path = str(tmp_path / "step_000001")
        ckpt.save(path, self._tree(), step=1)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        assert all("crc32" in leaf for leaf in manifest["leaves"])

    def test_corrupt_leaf_raises_typed(self, tmp_path):
        from repro.core.health import CheckpointCorruptError, GPICError

        path = str(tmp_path / "step_000001")
        ckpt.save(path, self._tree(), step=1)
        leaf = os.path.join(path, "leaf_00001.npy")
        raw = bytearray(open(leaf, "rb").read())
        raw[-4:] = b"\xde\xad\xbe\xef"
        open(leaf, "wb").write(bytes(raw))
        with pytest.raises(CheckpointCorruptError, match="checksum"):
            ckpt.restore(path, self._tree())
        assert issubclass(CheckpointCorruptError, GPICError)

    def test_truncated_leaf_raises_typed(self, tmp_path):
        from repro.core.health import CheckpointCorruptError

        path = str(tmp_path / "step_000001")
        ckpt.save(path, self._tree(), step=1)
        leaf = os.path.join(path, "leaf_00000.npy")
        raw = open(leaf, "rb").read()
        open(leaf, "wb").write(raw[: len(raw) // 2])
        with pytest.raises(CheckpointCorruptError):
            ckpt.restore(path, self._tree())

    def test_missing_leaf_raises_typed(self, tmp_path):
        from repro.core.health import CheckpointCorruptError

        path = str(tmp_path / "step_000001")
        ckpt.save(path, self._tree(), step=1)
        os.remove(os.path.join(path, "leaf_00001.npy"))
        with pytest.raises(CheckpointCorruptError, match="missing"):
            ckpt.restore(path, self._tree())

    def test_unreadable_manifest_raises_typed(self, tmp_path):
        from repro.core.health import CheckpointCorruptError

        path = str(tmp_path / "step_000001")
        ckpt.save(path, self._tree(), step=1)
        open(os.path.join(path, "manifest.json"), "w").write("{not json")
        with pytest.raises(CheckpointCorruptError, match="manifest"):
            ckpt.restore(path, self._tree())

    def test_pre_crc_manifest_restores_unchecked(self, tmp_path):
        """Backward compat: manifests written before the checksum field
        (or by older code) restore without the integrity check."""
        import json

        path = str(tmp_path / "step_000001")
        ckpt.save(path, self._tree(), step=1)
        mpath = os.path.join(path, "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        for leaf in manifest["leaves"]:
            del leaf["crc32"]
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        tree, step = ckpt.restore(path, self._tree())
        assert step == 1
        assert np.array_equal(np.asarray(tree["w"]),
                              np.asarray(self._tree()["w"]))

    def test_quarantine_hides_from_latest_step(self, tmp_path):
        root = str(tmp_path)
        for s in (1, 2):
            ckpt.save(os.path.join(root, f"step_{s:06d}"), self._tree(),
                      step=s)
        newest = ckpt.latest_step(root)
        moved = ckpt.quarantine(newest)
        assert os.path.isdir(moved)
        assert ckpt.latest_step(root).endswith("step_000001")

    def test_restore_latest_valid_skips_corrupt(self, tmp_path):
        root = str(tmp_path)
        for s in (1, 2, 3):
            ckpt.save(os.path.join(root, f"step_{s:06d}"),
                      jax.tree_util.tree_map(lambda a, s=s: a + s,
                                             self._tree()), step=s)
        for s in (2, 3):  # corrupt the two newest
            leaf = os.path.join(root, f"step_{s:06d}", "leaf_00000.npy")
            raw = bytearray(open(leaf, "rb").read())
            raw[-4:] = b"\x00\x00\x00\x00"
            open(leaf, "wb").write(bytes(raw))
        tree, step, path, skipped = ckpt.restore_latest_valid(
            root, self._tree())
        assert step == 1 and path.endswith("step_000001")
        assert len(skipped) == 2
        assert np.array_equal(np.asarray(tree["b"]),
                              np.ones(4, np.float32) + 1)

    def test_restore_latest_valid_none_when_all_corrupt(self, tmp_path):
        root = str(tmp_path)
        ckpt.save(os.path.join(root, "step_000001"), self._tree(), step=1)
        os.remove(os.path.join(root, "step_000001", "manifest.json"))
        tree, step, path, skipped = ckpt.restore_latest_valid(
            root, self._tree())
        assert tree is None and step is None and path is None
        assert len(skipped) == 1


class TestAsyncCheckpointerDirect:
    """save_async/wait ordering and overlapping saves (PR 9 satellite —
    previously only exercised through RestartableLoop)."""

    def test_wait_without_save_is_noop(self):
        ckpt.AsyncCheckpointer().wait()  # must not raise

    def test_save_async_then_wait_lands_checkpoint(self, tmp_path):
        saver = ckpt.AsyncCheckpointer()
        path = str(tmp_path / "step_000003")
        tree = {"v": jnp.arange(8.0)}
        saver.save_async(path, tree, step=3)
        saver.wait()
        restored, step = ckpt.restore(path, tree)
        assert step == 3
        assert np.array_equal(np.asarray(restored["v"]), np.arange(8.0))

    def test_wait_is_idempotent(self, tmp_path):
        saver = ckpt.AsyncCheckpointer()
        saver.save_async(str(tmp_path / "step_000001"),
                         {"v": jnp.zeros(4)}, step=1)
        saver.wait()
        saver.wait()  # second wait: thread already joined and cleared

    def test_overlapping_saves_serialize(self, tmp_path):
        """A second save_async blocks on the first (double buffering): both
        checkpoints land, distinct and complete, and latest_step sees the
        newest."""
        saver = ckpt.AsyncCheckpointer()
        for s in range(1, 5):
            saver.save_async(str(tmp_path / f"step_{s:06d}"),
                             {"v": jnp.full((64,), float(s))}, step=s)
        saver.wait()
        assert ckpt.latest_step(str(tmp_path)).endswith("step_000004")
        for s in range(1, 5):
            tree, step = ckpt.restore(str(tmp_path / f"step_{s:06d}"),
                                      {"v": jnp.zeros(64)})
            assert step == s
            assert np.array_equal(np.asarray(tree["v"]),
                                  np.full((64,), float(s)))

    def test_snapshot_taken_at_call_time(self, tmp_path):
        """The host snapshot happens on the caller thread at save_async
        time — rebinding/updating the tree afterwards must not leak into
        the checkpoint."""
        saver = ckpt.AsyncCheckpointer()
        v = jnp.zeros(16)
        saver.save_async(str(tmp_path / "step_000001"), {"v": v}, step=1)
        v = v + 99.0  # the functional update the train loop would do next
        saver.wait()
        tree, _ = ckpt.restore(str(tmp_path / "step_000001"),
                               {"v": jnp.zeros(16)})
        assert np.array_equal(np.asarray(tree["v"]), np.zeros(16))


class TestRestartableLoopResume:
    """Resume-after-kill: the process dies (injector past max_restarts), a
    NEW loop object restores from disk and finishes bit-exactly."""

    def _setup(self):
        def step_fn(state, batch):
            new = state + batch
            return new, {"s": jnp.sum(new)}

        def data_fn(step):
            return jnp.full((4,), float(step + 1))

        return step_fn, data_fn, jnp.zeros(4)

    def test_resume_after_kill_is_bit_exact(self, tmp_path):
        step_fn, data_fn, s0 = self._setup()
        # uninterrupted reference
        ref_loop = RestartableLoop(step_fn, data_fn,
                                   str(tmp_path / "ref"), ckpt_every=3)
        ref_state, ref_step, _ = ref_loop.run(s0, 10)
        # killed run: injector fires at step 7 with no restarts allowed
        d = str(tmp_path / "killed")
        loop1 = RestartableLoop(
            step_fn, data_fn, d, ckpt_every=3, max_restarts=0,
            injector=FailureInjector(fail_at_steps=(7,)))
        with pytest.raises(SimulatedFailure):
            loop1.run(s0, 10)
        if loop1.saver:
            loop1.saver.wait()
        # a fresh loop (new process) restores the newest checkpoint and
        # resumes — final state identical to the uninterrupted run
        loop2 = RestartableLoop(step_fn, data_fn, d, ckpt_every=3)
        restored = loop2._restore(s0)
        assert restored is not None
        state, step = restored
        assert step == 6  # ckpt_every=3 → newest snapshot before the kill
        state, step, _ = loop2.run(state, 10, start_step=step)
        assert step == ref_step == 10
        assert np.array_equal(np.asarray(state), np.asarray(ref_state))

    def test_internal_restart_matches_fresh_resume(self, tmp_path):
        """The loop's own catch-restore path and a manual restore from the
        same directory agree."""
        step_fn, data_fn, s0 = self._setup()
        loop = RestartableLoop(
            step_fn, data_fn, str(tmp_path / "auto"), ckpt_every=2,
            max_restarts=3, injector=FailureInjector(fail_at_steps=(3, 5)))
        state, step, _ = loop.run(s0, 8)
        assert loop.restarts == 2 and step == 8
        ref_loop = RestartableLoop(step_fn, data_fn,
                                   str(tmp_path / "ref2"), ckpt_every=2)
        ref_state, _, _ = ref_loop.run(s0, 8)
        assert np.array_equal(np.asarray(state), np.asarray(ref_state))
