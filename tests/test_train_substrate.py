"""Tests for optimizer, checkpointing, fault tolerance and compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_smoke_config
from repro.models import get_api, make_train_batch
from repro.train import adamw_init, build_train_step, lr_schedule
from repro.train import checkpoint as ckpt
from repro.train.compression import (
    ErrorFeedback,
    compress_decompress,
    dequantize_int8,
    ef_compress,
    quantize_int8,
)
from repro.train.fault_tolerance import (
    FailureInjector,
    RestartableLoop,
    SimulatedFailure,
    StragglerMonitor,
)

TCFG = TrainConfig(compute_dtype="float32", remat="none",
                   learning_rate=1e-3, warmup_steps=2, total_steps=100)


class TestOptimizer:
    def test_lr_schedule_warmup_and_decay(self):
        cfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(lr_schedule(jnp.int32(s), cfg)) for s in
               [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(5e-4)
        assert lrs[2] == pytest.approx(1e-3)
        assert lrs[3] < lrs[2]
        assert lrs[4] == pytest.approx(1e-4, rel=1e-3)  # 10% floor

    def test_adamw_reduces_loss_on_quadratic(self):
        from repro.train.optimizer import adamw_update
        cfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=1000,
                          weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(params, g, state, cfg)
        assert float(loss(params)) < 1e-2


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": {"c": jnp.ones((2,), jnp.bfloat16),
                      "d": jnp.int32(7)}}
        path = str(tmp_path / "step_000001")
        ckpt.save(path, tree, step=1)
        restored, step = ckpt.restore(path, jax.tree.map(lambda x: x, tree))
        assert step == 1
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_overwrite(self, tmp_path):
        path = str(tmp_path / "step_000002")
        ckpt.save(path, {"x": jnp.zeros(3)}, step=2)
        ckpt.save(path, {"x": jnp.ones(3)}, step=2)
        restored, _ = ckpt.restore(path, {"x": jnp.zeros(3)})
        np.testing.assert_array_equal(np.asarray(restored["x"]), 1.0)

    def test_async_checkpointer(self, tmp_path):
        saver = ckpt.AsyncCheckpointer()
        path = str(tmp_path / "step_000003")
        saver.save_async(path, {"x": jnp.full((4,), 3.0)}, step=3)
        saver.wait()
        restored, step = ckpt.restore(path, {"x": jnp.zeros(4)})
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["x"]), 3.0)

    def test_latest_step(self, tmp_path):
        for s in (1, 5, 3):
            ckpt.save(str(tmp_path / f"step_{s:06d}"), {"x": jnp.zeros(1)},
                      step=s)
        assert ckpt.latest_step(str(tmp_path)).endswith("step_000005")


class TestFaultTolerance:
    def _make_loop(self, tmp_path, injector=None, ckpt_every=3):
        cfg = get_smoke_config("stablelm-3b")
        api = get_api(cfg)
        params = api.init_params(jax.random.key(0), cfg)
        opt = adamw_init(params)
        tstep = jax.jit(build_train_step(cfg, TCFG))

        def step_fn(state, batch):
            p, o = state
            p, o, m = tstep(p, o, batch)
            return (p, o), m

        def data_fn(step):
            return make_train_batch(cfg, 2, 16, 1000 + step)

        loop = RestartableLoop(step_fn, data_fn, str(tmp_path),
                               ckpt_every=ckpt_every, injector=injector,
                               async_save=False)
        return loop, (params, opt)

    def test_restart_is_bit_exact(self, tmp_path):
        """A crash + restore must reproduce the uninterrupted run exactly."""
        loop_a, state0 = self._make_loop(tmp_path / "a")
        final_a, step_a, _ = loop_a.run(state0, 10)

        inj = FailureInjector(fail_at_steps=[7])
        loop_b, state0b = self._make_loop(tmp_path / "b", injector=inj)
        final_b, step_b, _ = loop_b.run(state0b, 10)

        assert step_a == step_b == 10
        assert loop_b.restarts == 1
        for a, b in zip(jax.tree.leaves(final_a), jax.tree.leaves(final_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_multiple_failures(self, tmp_path):
        inj = FailureInjector(fail_at_steps=[2, 5, 8])
        loop, state0 = self._make_loop(tmp_path, injector=inj)
        _, step, _ = loop.run(state0, 10)
        assert step == 10
        assert loop.restarts == 3

    def test_straggler_monitor(self):
        mon = StragglerMonitor(threshold=2.0)
        for i in range(10):
            assert not mon.record(i, 0.1)
        assert mon.record(10, 0.5)          # 5x median -> flagged
        assert len(mon.flagged) == 1


class TestFaultTolerancePrimitives:
    """Direct unit tests of the fault-tolerance primitives on trivial
    state, independent of the model train step (the robustness suite
    drives the same pieces through ClusteringFaultHarness)."""

    @staticmethod
    def _loop(tmp_path, injector=None, ckpt_every=2):
        # state is one scalar; step t adds data_fn(t) — a pure, replayable
        # step whose exact final value is the sum of the batch sequence
        def step_fn(state, batch):
            s = state["s"] + batch
            return {"s": s}, {"s": float(s)}

        def data_fn(step):
            return jnp.float32(step + 1)

        return RestartableLoop(step_fn, data_fn, str(tmp_path),
                               ckpt_every=ckpt_every, injector=injector,
                               async_save=False)

    def test_save_restore_roundtrip(self, tmp_path):
        loop = self._loop(tmp_path)
        state = {"s": jnp.float32(41.5)}
        loop._save(state, 7)
        restored, step = loop._restore({"s": jnp.float32(0.0)})
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["s"]),
                                      np.asarray(state["s"]))

    def test_restore_without_checkpoint_is_none(self, tmp_path):
        assert self._loop(tmp_path / "empty")._restore(
            {"s": jnp.float32(0.0)}) is None

    def test_restore_picks_latest_step(self, tmp_path):
        loop = self._loop(tmp_path)
        for step in (2, 10, 6):
            loop._save({"s": jnp.float32(step)}, step)
        _, step = loop._restore({"s": jnp.float32(0.0)})
        assert step == 10

    def test_crash_replay_is_exact(self, tmp_path):
        # sum(1..8) = 36 regardless of a crash at step 5 (between saves)
        inj = FailureInjector(fail_at_steps=[5])
        loop = self._loop(tmp_path, injector=inj)
        state, step, log = loop.run({"s": jnp.float32(0.0)}, 8)
        assert step == 8 and loop.restarts == 1
        assert float(state["s"]) == 36.0
        # the replayed steps appear twice in the metrics log; the final
        # values per step are the uninterrupted ones
        assert log[-1]["s"] == 36.0

    def test_injector_fires_once_per_step(self):
        inj = FailureInjector(fail_at_steps=[3])
        with pytest.raises(SimulatedFailure):
            inj.maybe_fail(3)
        inj.maybe_fail(3)   # second visit passes (fire-once accounting)
        inj.maybe_fail(4)
        assert inj.fired == {3}

    def test_straggler_needs_warmup_samples(self):
        # fewer than 5 samples never flags, however slow the step
        mon = StragglerMonitor(threshold=2.0)
        for i in range(4):
            assert not mon.record(i, 10.0 if i == 3 else 0.1)
        assert mon.flagged == []

    def test_straggler_threshold_boundary(self):
        mon = StragglerMonitor(threshold=2.0)
        for i in range(8):
            mon.record(i, 1.0)
        assert not mon.record(8, 2.0)   # == threshold*median: not flagged
        assert mon.record(9, 2.0001)    # just above: flagged
        assert mon.flagged[-1][0] == 9

    def test_straggler_window_eviction(self):
        mon = StragglerMonitor(threshold=2.0, window=5)
        for i in range(5):
            mon.record(i, 1.0)
        for i in range(5, 10):
            mon.record(i, 100.0)        # first flags, then shifts the median
        assert mon.median == 100.0
        assert not mon.record(10, 150.0)  # 1.5x new median: healthy again


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.key(0), (64, 128))
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(jnp.max(err)) <= float(jnp.max(s)) * 0.51

    def test_compress_preserves_structure(self):
        g = {"a": jnp.ones((4, 4)), "b": {"c": jnp.zeros((3,))}}
        dq, err = compress_decompress(g)
        assert jax.tree_util.tree_structure(dq) == jax.tree_util.tree_structure(g)

    def test_error_feedback_converges(self):
        """EF-SGD on a quadratic: with feedback the bias vanishes; without,
        aggressive quantization stalls progress sooner."""
        w = jnp.array([1.0, -2.0, 3.0, -4.0])
        target = jnp.zeros(4)

        def grad(w):
            return 2 * (w - target)

        # with error feedback
        w_ef = w
        ef = ErrorFeedback.init({"w": w})
        for _ in range(300):
            g = {"w": grad(w_ef)}
            dq, ef = ef_compress(g, ef)
            w_ef = w_ef - 0.05 * dq["w"]
        assert float(jnp.max(jnp.abs(w_ef))) < 1e-2

    def test_train_step_with_compression_runs(self):
        cfg = get_smoke_config("stablelm-3b")
        api = get_api(cfg)
        params = api.init_params(jax.random.key(0), cfg)
        opt = adamw_init(params)
        tcfg = TrainConfig(**{**TCFG.__dict__, "gradient_compression": True})
        step = jax.jit(build_train_step(cfg, tcfg))
        batch = make_train_batch(cfg, 2, 16, 0)
        _, _, m = step(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
