"""Multi-device distributed GPIC tests.

These run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single-device view (per the dry-run rules).
"""
import textwrap

import pytest

from conftest import run_in_mesh_subprocess


def _run_in_subprocess(body: str) -> str:
    code = textwrap.dedent(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.data import gaussians, three_circles
        from repro.core import pic_reference, adjusted_rand_index
        from repro.data.synthetic import gaussians as gaussians_k
        from repro.core.distributed import (
            distributed_gpic, distributed_gpic_matrix_free, shard_points)
        mesh = jax.make_mesh((8,), ("data",))
        """
    ) + textwrap.dedent(body)
    return run_in_mesh_subprocess(code, timeout=600)


@pytest.mark.slow
def test_distributed_explicit_matches_reference():
    out = _run_in_subprocess(
        """
        x, y = gaussians(640, seed=0)
        xs = shard_points(x, mesh, "data")
        res = distributed_gpic(xs, 4, key=jax.random.key(1), mesh=mesh,
                               affinity_kind="rbf", sigma=0.3, max_iter=300)
        ref = pic_reference(jnp.asarray(x), 4, key=jax.random.key(1),
                            affinity_kind="rbf", sigma=0.3, max_iter=300)
        err = float(jnp.max(jnp.abs(ref.embedding - res.embedding)))
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        assert err < 1e-6, err
        assert ari > 0.95, ari
        assert int(res.n_iter) == int(ref.n_iter)
        print("OK", err, ari)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_distributed_matrix_free_quality_and_scale():
    out = _run_in_subprocess(
        """
        x, y = gaussians(8000, k=3, seed=0)
        xs = shard_points(x, mesh, "data")
        res = distributed_gpic_matrix_free(
            xs, 3, key=jax.random.key(1), mesh=mesh,
            affinity_kind="cosine_shifted", max_iter=50)
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        assert np.isfinite(np.asarray(res.embedding)).all()
        assert ari > 0.9, ari
        print("OK", ari)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_distributed_multi_axis_mesh():
    """Rows sharded over BOTH axes of a 2-D mesh (multi-pod structure)."""
    out = _run_in_subprocess(
        """
        mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
        x, y = three_circles(480, seed=0)
        xs = shard_points(x, mesh2, ("pod", "data"))
        res = distributed_gpic(xs, 3, key=jax.random.key(1), mesh=mesh2,
                               shard_axes=("pod", "data"),
                               affinity_kind="rbf", sigma=0.3, max_iter=300)
        ref = pic_reference(jnp.asarray(x), 3, key=jax.random.key(1),
                            affinity_kind="rbf", sigma=0.3, max_iter=300)
        err = float(jnp.max(jnp.abs(ref.embedding - res.embedding)))
        assert err < 1e-5, err
        print("OK", err)
        """
    )
    assert "OK" in out
