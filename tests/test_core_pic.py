"""Unit + behaviour tests for the core PIC/GPIC algorithm."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    adjusted_rand_index,
    affinity_chunked,
    affinity_matrix,
    degree_matrix_free,
    matvec_matrix_free,
    pic_from_affinity,
    pic_reference,
    pic_serial_numpy,
    row_normalize_features,
)
from repro.data import cassini, gaussians, shapes, smiley, three_circles, two_moons


class TestAffinity:
    def test_cosine_symmetric_zero_diag(self):
        x = jax.random.normal(jax.random.key(0), (64, 5))
        a = affinity_matrix(x, "cosine")
        np.testing.assert_allclose(a, a.T, atol=1e-6)
        np.testing.assert_allclose(np.diag(np.asarray(a)), 0.0, atol=1e-7)

    def test_cosine_shifted_nonneg(self):
        x = jax.random.normal(jax.random.key(1), (64, 3))
        a = affinity_matrix(x, "cosine_shifted")
        assert float(jnp.min(a)) >= -1e-6

    def test_rbf_range(self):
        x = jax.random.normal(jax.random.key(2), (64, 2))
        a = affinity_matrix(x, "rbf", sigma=0.5)
        assert float(jnp.min(a)) >= 0.0
        assert float(jnp.max(a)) <= 1.0 + 1e-6

    @pytest.mark.parametrize("kind", ["cosine", "cosine_shifted", "rbf"])
    def test_chunked_matches_dense(self, kind):
        x = jax.random.normal(jax.random.key(3), (100, 4))
        dense = affinity_matrix(x, kind, sigma=0.7)
        chunked = affinity_chunked(x, kind, sigma=0.7, chunk=33)
        np.testing.assert_allclose(dense, chunked, atol=1e-5)

    @pytest.mark.parametrize("kind", ["cosine", "cosine_shifted"])
    def test_matrix_free_matvec_exact(self, kind):
        """O2: factored A·v must equal the explicit product (DESIGN.md §2)."""
        x = jax.random.normal(jax.random.key(4), (80, 6))
        xn = row_normalize_features(x)
        a = affinity_matrix(x, kind)
        v = jax.random.uniform(jax.random.key(5), (80,))
        np.testing.assert_allclose(
            a @ v, matvec_matrix_free(xn, v, kind), atol=2e-4, rtol=1e-4
        )

    def test_matrix_free_degree(self):
        x = jax.random.normal(jax.random.key(6), (50, 3))
        xn = row_normalize_features(x)
        a = affinity_matrix(x, "cosine_shifted")
        np.testing.assert_allclose(
            jnp.sum(a, axis=1),
            degree_matrix_free(xn, "cosine_shifted"),
            atol=2e-4, rtol=1e-4,
        )


class TestPICBehaviour:
    @pytest.mark.parametrize(
        "gen,k,sigma,n_vectors,embedding",
        [
            # xfail'd PR 1 → passing PR 3: the 1-D PIC embedding collapses
            # two of the three concentric circles (ARI 0.811) and
            # multi-vector random restarts measured worse (0.50-0.61); the
            # orthogonalized 2-column block embedding (DESIGN.md §10)
            # separates all three (ARI 1.0) — the embedding-quality fix
            # the xfail note asked for. The classic-embedding floor for
            # this dataset is tracked in tests/test_embedding_quality.py.
            (three_circles, 3, 0.3, 2, "orthogonal"),
            (cassini, 3, 0.3, 1, "pic"),
            (gaussians, 4, 0.3, 1, "pic"),
            (shapes, 4, 0.3, 1, "pic"),
            (smiley, 4, 0.15, 1, "pic"),
        ],
    )
    def test_clusters_separable_datasets(self, gen, k, sigma, n_vectors,
                                         embedding):
        x, y = gen(480, seed=0)
        res = pic_reference(
            jnp.asarray(x), k, key=jax.random.key(1),
            affinity_kind="rbf", sigma=sigma, max_iter=400,
            n_vectors=n_vectors, embedding=embedding,
        )
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        assert ari >= 0.9, f"ARI {ari:.3f} too low"

    def test_moons_multivector(self):
        x, y = two_moons(480, seed=0)
        res = pic_reference(
            jnp.asarray(x), 2, key=jax.random.key(1),
            affinity_kind="rbf", sigma=0.25, max_iter=400, n_vectors=4,
        )
        ari = adjusted_rand_index(y, np.asarray(res.labels))
        assert ari >= 0.4

    def test_stops_by_epsilon(self):
        x, _ = gaussians(200, seed=0)
        res = pic_reference(
            jnp.asarray(x), 4, key=jax.random.key(0),
            affinity_kind="rbf", sigma=0.3, max_iter=500,
        )
        assert bool(res.converged)
        assert int(res.n_iter) < 500

    def test_embedding_l1_normalized(self):
        x, _ = gaussians(128, seed=1)
        res = pic_reference(jnp.asarray(x), 4, key=jax.random.key(0),
                            affinity_kind="rbf", sigma=0.3)
        assert abs(float(jnp.sum(jnp.abs(res.embedding))) - 1.0) < 1e-4

    def test_serial_numpy_matches_jax_embedding(self):
        """Paper claim: the parallel method converges to the same result."""
        x, _ = gaussians(160, seed=2)
        _, v_serial, _ = pic_serial_numpy(
            x, 4, affinity_kind="rbf", sigma=0.3, max_iter=100,
            return_timings=True,
        )
        a = affinity_matrix(jnp.asarray(x), "rbf", sigma=0.3)
        res = pic_from_affinity(a, 4, key=jax.random.key(0), max_iter=100)
        np.testing.assert_allclose(
            v_serial, np.asarray(res.embedding), atol=1e-5, rtol=1e-3
        )

    def test_serial_affinity_dominates(self):
        """Table 1 structure: the O(n^2 m) affinity stage dominates the serial
        runtime (the paper reports 73-99 %). With m=2 and BLAS rows the margin
        is noise-thin, so exercise the general m=16 case (random lift)."""
        x, _ = two_moons(2500, seed=0)
        rng = np.random.default_rng(0)
        lift = rng.standard_normal((2, 32)).astype(np.float32)
        x32 = x @ lift
        _, _, tm = pic_serial_numpy(x32, 2, affinity_kind="cosine_shifted",
                                    max_iter=3, return_timings=True)
        assert tm["affinity_s"] > 0.5 * (tm["affinity_s"] + tm["power_s"])


class TestPermutationInvariance:
    def test_labels_permute_with_input(self):
        x, _ = gaussians(180, seed=3)
        perm = np.random.default_rng(0).permutation(180)
        r1 = pic_reference(jnp.asarray(x), 4, key=jax.random.key(0),
                           affinity_kind="rbf", sigma=0.3, max_iter=300)
        r2 = pic_reference(jnp.asarray(x[perm]), 4, key=jax.random.key(0),
                           affinity_kind="rbf", sigma=0.3, max_iter=300)
        ari = adjusted_rand_index(np.asarray(r1.labels)[perm], np.asarray(r2.labels))
        assert ari >= 0.95
