"""Distributed vs single-device parity for the unified operator pipeline.

One convergence engine (core/power.batched_power_iteration) backs every
entry point; these tests assert the observable consequence: an 8-device
host mesh produces IDENTICAL labels and per-column iteration counts to the
single-device run of the same engine, for all three paths (explicit Pallas
stripes, the A-free streaming ring, and the factored matrix-free product),
across affinity kinds and n_vectors ∈ {1, 4}.

Each affinity kind runs on data where its clustering is well-conditioned
(decision boundaries far from any point), so label parity is exact rather
than modulo boundary-point noise at the f32 floor:

  cosine_shifted → two antipodal blobs (inter-cluster affinity ~0)
  cosine         → two angular blobs 60° apart (degrees healthy-positive;
                   raw cosine on signed data has near-zero degrees)
  rbf            → three spatially separated blobs

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single-device view.
"""
import textwrap

import pytest

from conftest import run_in_mesh_subprocess

_PRELUDE = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import GPICConfig, run_gpic
    from repro.core.distributed import shard_points
    from repro.data.synthetic import gaussians

    mesh = jax.make_mesh((8,), ("data",))

    def datasets():
        rng = np.random.default_rng(0)
        angs = np.concatenate([rng.normal(0.3, 0.08, 256),
                               rng.normal(1.35, 0.08, 256)])
        radii = rng.uniform(1.0, 3.0, 512)
        angular = np.stack([radii * np.cos(angs), radii * np.sin(angs)],
                           axis=1).astype(np.float32)
        return {
            "cosine_shifted": (gaussians(512, k=2, seed=0)[0], 2),
            "cosine": (angular, 2),
            "rbf": (gaussians(512, k=3, seed=0)[0], 3),
        }

    def check(path, kinds):
        data = datasets()
        for kind in kinds:
            x, k = data[kind]
            xs = shard_points(x, mesh, "data")
            for r in (1, 4):
                cfg = GPICConfig(engine=path, affinity_kind=kind, sigma=0.3,
                                 n_vectors=r, max_iter=100)
                key = jax.random.key(1)
                sd = run_gpic(jnp.asarray(x), k, cfg, key=key)
                dist = run_gpic(xs, k, cfg.with_(mesh=mesh), key=key)
                labels_eq = bool((np.asarray(sd.labels)
                                  == np.asarray(dist.labels)).all())
                iters_eq = bool((np.asarray(sd.n_iter_cols)
                                 == np.asarray(dist.n_iter_cols)).all())
                assert labels_eq, (path, kind, r, "labels diverged")
                assert iters_eq, (path, kind, r,
                                  np.asarray(sd.n_iter_cols),
                                  np.asarray(dist.n_iter_cols))
                assert int(sd.n_iter) == int(dist.n_iter)
                print("OK", path, kind, "r=", r,
                      "iters=", np.asarray(dist.n_iter_cols).tolist())
    """


def _run_in_subprocess(body: str) -> str:
    return run_in_mesh_subprocess(
        textwrap.dedent(_PRELUDE) + textwrap.dedent(body))


@pytest.mark.slow
def test_parity_explicit():
    """Sharded explicit stripes == single-device explicit engine."""
    out = _run_in_subprocess(
        'check("explicit", ("cosine_shifted", "cosine", "rbf"))')
    assert out.count("OK") == 6


@pytest.mark.slow
def test_parity_streaming():
    """The sharded streaming ring (the new production path) clusters
    identically to the single-device streaming engine — the ISSUE 2
    acceptance case — for every affinity kind and r ∈ {1, 4}."""
    out = _run_in_subprocess(
        'check("streaming", ("cosine_shifted", "cosine", "rbf"))')
    assert out.count("OK") == 6


@pytest.mark.slow
def test_parity_matrix_free():
    """Sharded matrix-free == single-device matrix-free (cosine kinds)."""
    out = _run_in_subprocess(
        'check("matrix_free", ("cosine_shifted", "cosine"))')
    assert out.count("OK") == 4


@pytest.mark.slow
def test_parity_embedding_modes():
    """The ISSUE 3 parity case: the orthogonal (block-QR) and ensemble
    (diffusion-snapshot) embedding modes produce IDENTICAL labels and
    per-column iteration counts on the 8-device mesh vs single device, for
    all three engines — the QR's Gram partials reduce through the
    operator's psum binding and snapshots gather once after the loop, so
    the sharded block algebra IS the single-device one. The result also
    records which embedding mode produced its matrix (PICResult
    .embedding_mode), asserted on both sides.

    Config notes: r values are pinned per engine where the later columns'
    eps-crossing is reduction-order robust (the same well-conditioned-data
    discipline as the classic parity suite, DESIGN.md §9/§10); the
    matrix-free psum ordering makes its r∈{1,2} ensemble crossings
    boundary-sensitive, so it runs r=4.
    """
    out = _run_in_subprocess(
        """
        x, _ = gaussians(512, k=3, seed=0)
        k = 3
        xs = shard_points(x, mesh, "data")
        combos = [("explicit", "rbf", "orthogonal", 2),
                  ("streaming", "rbf", "orthogonal", 2),
                  ("streaming", "rbf", "orthogonal", 4),
                  ("matrix_free", "cosine_shifted", "orthogonal", 2),
                  ("matrix_free", "cosine_shifted", "orthogonal", 4),
                  ("explicit", "rbf", "ensemble", 2),
                  ("streaming", "rbf", "ensemble", 2),
                  ("matrix_free", "cosine_shifted", "ensemble", 4)]
        for path, kind, emb, r in combos:
            cfg = GPICConfig(engine=path, affinity_kind=kind, sigma=0.3,
                             n_vectors=r, max_iter=100, embedding=emb)
            key = jax.random.key(1)
            sd = run_gpic(jnp.asarray(x), k, cfg, key=key)
            dist = run_gpic(xs, k, cfg.with_(mesh=mesh), key=key)
            assert sd.embedding_mode == emb, (path, emb, r, "sd mode")
            assert dist.embedding_mode == emb, (path, emb, r, "dist mode")
            assert sd.embeddings.shape == dist.embeddings.shape, (
                path, emb, r, sd.embeddings.shape, dist.embeddings.shape)
            assert (np.asarray(sd.labels) == np.asarray(dist.labels)).all(), (
                path, emb, r, "labels diverged")
            assert (np.asarray(sd.n_iter_cols)
                    == np.asarray(dist.n_iter_cols)).all(), (
                path, emb, r, np.asarray(sd.n_iter_cols),
                np.asarray(dist.n_iter_cols))
            print("OK", path, emb, "r=", r,
                  "iters=", np.asarray(dist.n_iter_cols).tolist())
        """
    )
    assert out.count("OK") == 8


@pytest.mark.slow
def test_parity_affinity_specs():
    """The ISSUE 5 parity case: adaptive local scaling and kNN truncation
    (AffinitySpec, DESIGN.md §11) produce IDENTICAL labels and per-column
    iteration counts on the 8-device mesh vs single device for the
    explicit stripe build AND the streaming ring — pass 1 runs as stripe /
    ring row-top-k reductions whose merged statistics equal the
    single-device pass bitwise, so only the usual l1/psum reduction-order
    noise remains (r pinned per combo where the late-column eps-crossings
    are robust, the §9(b)/§10 discipline; the matrix-free engine rejects
    non-factorable specs by design — asserted here too). The last combo
    arms the subspace residual stopping rule on a truncated graph: the
    residual reduces through op.gram/psum, so the early stop must fire at
    the identical sweep on both sides.
    """
    out = _run_in_subprocess(
        """
        from repro.core import AffinitySpec
        x, _ = gaussians(512, k=3, seed=0)
        k = 3
        xs = shard_points(x, mesh, "data")
        knn = AffinitySpec(kind="rbf", sigma=0.3, knn_k=10)
        ada = AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=7)
        both = AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=7,
                            knn_k=20)
        combos = [("explicit", knn, 4, {}),
                  ("streaming", knn, 2, {}),
                  ("explicit", ada, 2, {}),
                  ("streaming", ada, 1, {}),
                  ("explicit", both, 2, {}),
                  ("streaming", both, 2, {}),
                  ("streaming", knn, 2,
                   dict(embedding="orthogonal", residual_tol=1e-3))]
        for path, spec, r, extra in combos:
            cfg = GPICConfig(engine=path, affinity=spec, n_vectors=r,
                             max_iter=100, **extra)
            key = jax.random.key(1)
            sd = run_gpic(jnp.asarray(x), k, cfg, key=key)
            dist = run_gpic(xs, k, cfg.with_(mesh=mesh), key=key)
            assert (np.asarray(sd.labels) == np.asarray(dist.labels)).all(), (
                path, spec, r, "labels diverged")
            assert (np.asarray(sd.n_iter_cols)
                    == np.asarray(dist.n_iter_cols)).all(), (
                path, spec, r, np.asarray(sd.n_iter_cols),
                np.asarray(dist.n_iter_cols))
            print("OK", path, spec.bandwidth, "knn=", spec.knn_k, "r=", r,
                  "iters=", np.asarray(dist.n_iter_cols).tolist())
        try:
            run_gpic(xs, k, GPICConfig(engine="matrix_free", affinity=knn,
                                       mesh=mesh), key=jax.random.key(1))
        except ValueError as e:
            assert "factorable" in str(e)
            print("OK matrix_free-rejects-knn")
        """
    )
    assert out.count("OK") == 8


@pytest.mark.slow
def test_streaming_ring_is_a_free():
    """The sharded streaming path's jaxpr contains no value as large as
    even one device's (n/P, n) affinity stripe — A is never materialized
    in any layout, which is the property that makes it the production
    configuration (O(n·m/P) residency; DESIGN.md §9). Checked for the
    dense spec AND an adaptive+kNN spec: the two-pass build's ring
    row-top-k (pass 1) must stay as lean as the sweeps it feeds."""
    out = _run_in_subprocess(
        """
        from repro.core import AffinitySpec
        from repro.core.distributed import distributed_gpic
        x, k = datasets()["rbf"]
        xs = shard_points(x, mesh, "data")
        spec = AffinitySpec(kind="rbf", bandwidth="adaptive", scale_k=7,
                            knn_k=10)
        jaxprs = [
            jax.make_jaxpr(
                lambda xv, kv: distributed_gpic(
                    xv, k, key=kv, mesh=mesh, engine="streaming",
                    affinity_kind="rbf", sigma=0.3, max_iter=10)
            )(xs, jax.random.key(1)),
            jax.make_jaxpr(
                lambda xv, kv: distributed_gpic(
                    xv, k, key=kv, mesh=mesh, engine="streaming",
                    affinity=spec, max_iter=10)
            )(xs, jax.random.key(1)),
        ]
        n = x.shape[0]
        stripe_elems = (n // 8) * n        # one device's A stripe

        def big(aval):
            shape = getattr(aval, "shape", ())
            dims = [s for s in shape if isinstance(s, int) and s > 1]
            if len(dims) < 2:
                return False
            total = 1
            for s in dims:
                total *= s
            return total >= stripe_elems

        def walk(jaxpr):
            for eqn in jaxpr.eqns:
                for var in list(eqn.invars) + list(eqn.outvars):
                    if hasattr(var, "aval") and big(var.aval):
                        return False
                for val in eqn.params.values():
                    vals = val if isinstance(val, (list, tuple)) else (val,)
                    for v in vals:
                        sub = getattr(v, "jaxpr", v)
                        if hasattr(sub, "eqns") and not walk(sub):
                            return False
            return True

        for jaxpr in jaxprs:
            assert walk(jaxpr.jaxpr), "streaming ring materialized a big array"
        print("OK ring-jaxpr-lean")
        """
    )
    assert "OK" in out
