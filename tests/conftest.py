"""Test-session config: deterministic mini-``hypothesis`` fallback.

This container has no ``hypothesis`` wheel and nothing may be pip-installed,
but three seed test modules import it at module scope — which previously
killed collection for those whole files. When the real package is missing we
install a small deterministic stand-in into ``sys.modules`` BEFORE
collection: ``@given`` draws ``max_examples`` pseudo-random samples per
strategy from a seed derived from the test name (stable across runs and
machines) and runs the test body once per sample. It implements exactly the
API surface this suite uses: ``given``, ``settings``, and the strategies
``integers``, ``floats``, ``lists``, ``sampled_from``, ``data``,
``composite``. When the real hypothesis IS available it is used untouched.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 25


def _install_hypothesis_fallback() -> None:
    class Strategy:
        def __init__(self, sample_fn):
            self._sample = sample_fn

        def sample(self, rng):
            return self._sample(rng)

    def integers(min_value, max_value):
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value):
        return Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(elements):
        seq = list(elements)
        return Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def lists(elements, *, min_size=0, max_size=10):
        def sample(rng):
            size = rng.randint(min_size, max_size)
            return [elements.sample(rng) for _ in range(size)]
        return Strategy(sample)

    class _DataObject:
        """The interactive draw handle ``@given(st.data())`` provides."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.sample(self._rng)

    class _DataStrategy(Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    def data():
        return _DataStrategy()

    def composite(fn):
        @functools.wraps(fn)
        def builder(*args, **kwargs):
            def sample(rng):
                return fn(_DataObject(rng).draw, *args, **kwargs)
            return Strategy(sample)
        return builder

    def given(*arg_strategies, **kw_strategies):
        def deco(test_fn):
            @functools.wraps(test_fn)
            def wrapper(*call_args, **call_kwargs):
                n_examples = getattr(
                    wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
                for i in range(n_examples):
                    rng = random.Random(f"{test_fn.__qualname__}:{i}")
                    drawn_args = tuple(s.sample(rng) for s in arg_strategies)
                    drawn_kw = {k: s.sample(rng)
                                for k, s in kw_strategies.items()}
                    try:
                        test_fn(*call_args, *drawn_args,
                                **{**drawn_kw, **call_kwargs})
                    except Exception:
                        print(f"falsifying example ({i + 1}/{n_examples}): "
                              f"args={drawn_args} kwargs={drawn_kw}",
                              file=sys.stderr)
                        raise
            # hide the strategy-filled parameters from pytest's fixture
            # resolution: expose only the params the runner must supply
            # (``self`` for methods), as real hypothesis does
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            sig = inspect.signature(test_fn)
            params = list(sig.parameters.values())
            keep = [p for p in params if p.name == "self"]
            remaining = [p for p in params if p.name != "self"]
            remaining = remaining[len(arg_strategies):]
            keep += [p for p in remaining if p.name not in kw_strategies]
            wrapper.__signature__ = sig.replace(parameters=keep)
            wrapper.hypothesis_fallback = True
            return wrapper
        return deco

    def settings(*_args, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None,
                 **_kwargs):
        def deco(fn):
            # cap the fallback's example count: it runs everything inline
            # (no shrinking, no database), so parity with real-hypothesis
            # run counts is not worth the wall-clock on CPU
            fn._fallback_max_examples = min(max_examples, 50)
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__version__ = "0.0-fallback"
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.lists = lists
    st.sampled_from = sampled_from
    st.data = data
    st.composite = composite
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401  (real package wins when present)
except ModuleNotFoundError:
    _install_hypothesis_fallback()


# Multi-device subprocess harness: the tests/ src/ layout means conftest
# must put src/ on sys.path itself before the repro import works when
# pytest is launched without PYTHONPATH.
_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.testing import run_mesh_subprocess as run_in_mesh_subprocess  # noqa: E402,F401
