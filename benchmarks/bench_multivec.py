"""Multi-vector power engine benchmark (ISSUE 1 acceptance evidence).

Three claims, measured on this container (CPU, kernels in interpret mode;
the ratios are structural, so they transfer to Mosaic on TPU):

  1. ONE A-sweep per iteration regardless of r: a batched engine power step
     at r=4 costs < 2x the r=1 step, while the seed-style per-vector path
     (r separate degree-normalized matvecs, the sweep count the old
     ``vmap``-of-while-loops produced) costs ~r x.
  2. The streaming engine clusters IDENTICALLY to the explicit-A engine
     (same labels, bitwise-equal embeddings at matching tile sizes) on the
     synthetic suite, for every affinity kind.
  3. The streaming path never allocates an (n, n) array: its jaxpr contains
     no value of shape (n, n) or larger in either dimension pair.

Run:  PYTHONPATH=src python -m benchmarks.run --only multivec
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gpic
from repro.core.affinity import row_normalize_features
from repro.data import gaussians, three_circles, two_moons
from repro.kernels import ops

from .common import csv_row, time_fn
from .roofline import sweep_model


def _engine_step(a, v, d, tile):
    """One batched engine power step (one A sweep for all columns)."""
    u = ops.degree_normalized_matmat(a, v, d, tm=tile, tn=tile)
    return u / jnp.maximum(jnp.sum(jnp.abs(u), axis=0, keepdims=True), 1e-30)


def _pervec_step(a, v, d, tile):
    """Seed-style step: one full A sweep PER column (what the old
    per-vector while-loops cost — r sweeps of A per iteration)."""
    cols = [
        ops.degree_normalized_matvec(a, v[:, c], d, tm=tile, tn=tile)
        for c in range(v.shape[1])
    ]
    u = jnp.stack(cols, axis=1)
    return u / jnp.maximum(jnp.sum(jnp.abs(u), axis=0, keepdims=True), 1e-30)


def _no_nn_values(closed_jaxpr, n: int) -> bool:
    """True iff no value anywhere in the jaxpr has two dims >= n."""

    def check_aval(aval) -> bool:
        shape = getattr(aval, "shape", ())
        return sum(1 for s in shape if isinstance(s, int) and s >= n) >= 2

    def subjaxprs(params):
        for val in params.values():
            vals = val if isinstance(val, (list, tuple)) else (val,)
            for v in vals:
                if hasattr(v, "eqns"):            # Jaxpr
                    yield v
                elif hasattr(v, "jaxpr"):         # ClosedJaxpr
                    yield v.jaxpr

    def walk(jaxpr) -> bool:
        for eqn in jaxpr.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                if hasattr(var, "aval") and check_aval(var.aval):
                    return False
            for sub in subjaxprs(eqn.params):
                if not walk(sub):
                    return False
        return True

    return walk(closed_jaxpr.jaxpr)


def run(n=1024, r=4, tile=256, steps=3):
    rows = []
    key = jax.random.key(0)
    x, _ = gaussians(n, seed=0)
    xn = row_normalize_features(jnp.asarray(x))
    a, d = ops.affinity_and_degree(xn, kind="cosine_shifted", tm=tile, tn=tile)
    v1 = jax.random.uniform(key, (n, 1))
    vr = jax.random.uniform(key, (n, r))

    def make_loop(step_fn):
        @jax.jit
        def f(v):
            for _ in range(steps):
                v = step_fn(a, v, d, tile)
            return v
        return f

    loop_eng = make_loop(_engine_step)
    loop_per = make_loop(_pervec_step)
    t_eng1, _ = time_fn(loop_eng, v1)
    t_engr, _ = time_fn(loop_eng, vr)
    t_perr, _ = time_fn(loop_per, vr)

    scale_eng = t_engr / t_eng1
    scale_per = t_perr / t_eng1
    one_sweep_ok = scale_eng < 2.0 and scale_per > scale_eng
    rows.append(csv_row(f"multivec/n={n}/engine_r=1", t_eng1,
                        f"sweeps_per_iter={sweep_model(n, 1, 'engine_explicit')['a_sweeps']}"))
    rows.append(csv_row(f"multivec/n={n}/engine_r={r}", t_engr,
                        f"scale_vs_r1={scale_eng:.2f}x "
                        f"sweeps_per_iter={sweep_model(n, r, 'engine_explicit')['a_sweeps']} "
                        f"one_sweep_scaling={'ok' if one_sweep_ok else 'DEGRADED'}"))
    rows.append(csv_row(f"multivec/n={n}/pervec_r={r}", t_perr,
                        f"scale_vs_r1={scale_per:.2f}x "
                        f"sweeps_per_iter={sweep_model(n, r, 'seed_pervec')['a_sweeps']}"))
    if os.environ.get("REPRO_BENCH_STRICT"):
        # timing ratios are load-sensitive — only hard-fail when a run
        # explicitly opts in (shared CI runners record DEGRADED instead)
        assert one_sweep_ok, (
            f"engine r={r} scaling {scale_eng:.2f}x (want < 2x) vs "
            f"per-vector {scale_per:.2f}x")

    # --- streaming == explicit on the synthetic suite --------------------
    suite = (
        ("two_moons", two_moons, 2, "rbf", 0.25),
        ("three_circles", three_circles, 3, "rbf", 0.3),
        ("gaussians", gaussians, 4, "cosine_shifted", 1.0),
    )
    for name, gen, k, kind, sigma in suite:
        xx = jnp.asarray(gen(512, seed=0)[0])
        kw = dict(key=jax.random.key(1), affinity_kind=kind, sigma=sigma,
                  max_iter=60, tile=tile)
        t_exp, res_e = time_fn(lambda: gpic(xx, k, engine="explicit", **kw))
        t_str, res_s = time_fn(lambda: gpic(xx, k, engine="streaming", **kw))
        same = bool((np.asarray(res_e.labels) == np.asarray(res_s.labels)).all())
        assert same, f"streaming labels diverged from explicit on {name}"
        rows.append(csv_row(f"multivec/suite/{name}/explicit", t_exp, ""))
        rows.append(csv_row(f"multivec/suite/{name}/streaming", t_str,
                            "labels_identical=true"))

    # --- streaming jaxpr is (n, n)-free ----------------------------------
    xx = jnp.asarray(gaussians(512, seed=0)[0])
    jaxpr = jax.make_jaxpr(
        lambda xv, kv: gpic(xv, 4, key=kv, engine="streaming",
                            affinity_kind="rbf", sigma=0.3, max_iter=10,
                            tile=128)
    )(xx, jax.random.key(0))
    nn_free = _no_nn_values(jaxpr, 512)
    assert nn_free, "streaming gpic jaxpr contains an (n, n)-sized value"
    rows.append(csv_row("multivec/streaming_jaxpr_nn_free", 0.0,
                        "no_nn_alloc=true"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
