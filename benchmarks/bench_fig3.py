"""Paper Figure 3: runtime-vs-n scaling curves (log-scale in the paper).

Explicit-A GPIC scales O(n²); the matrix-free path O(n·m) — the figure's
CSV shows both slopes plus the serial baseline at small n.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gpic, gpic_matrix_free, pic_serial_numpy
from repro.data import two_moons

from .common import csv_row, time_fn


def run(max_iter=3):
    rows = []
    key = jax.random.key(0)
    xw, _ = two_moons(64, seed=0)
    pic_serial_numpy(xw, 2, affinity_kind="cosine_shifted", max_iter=2)
    for n in (500, 1000, 2000, 4000):
        x, _ = two_moons(n, seed=0)
        _, _, tm = pic_serial_numpy(x, 2, affinity_kind="cosine_shifted",
                                    max_iter=max_iter, return_timings=True)
        rows.append(csv_row(f"fig3/serial/n={n}", tm["total_s"], ""))
    for n in (500, 1000, 2000, 4000, 8000):
        x, _ = two_moons(n, seed=0)
        xj = jnp.asarray(x)
        t, _ = time_fn(lambda: gpic(xj, 2, key=key, max_iter=max_iter,
                                    affinity_kind="cosine_shifted",
                                    use_pallas=False))
        rows.append(csv_row(f"fig3/gpic/n={n}", t, ""))
    for n in (500, 2000, 8000, 32000, 128000):
        x, _ = two_moons(n, seed=0)
        xj = jnp.asarray(x)
        t, _ = time_fn(lambda: gpic_matrix_free(xj, 2, key=key,
                                                max_iter=max_iter,
                                                affinity_kind="cosine_shifted"))
        rows.append(csv_row(f"fig3/gpic_mf/n={n}", t, ""))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
