"""Roofline report: aggregates experiments/dryrun/*.json into the §Roofline
table (per arch × shape × mesh: three terms, dominant bottleneck, MODEL_FLOPS
ratio)."""
from __future__ import annotations

import glob
import json
import os


def load(dryrun_dir="experiments/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(dryrun_dir="experiments/dryrun", mesh="16x16"):
    rows = []
    hdr = (f"{'arch':28s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collective':>10s} {'dominant':>10s} {'useful':>7s} {'frac':>6s}")
    rows.append(hdr)
    for c in load(dryrun_dir):
        if c.get("mesh") != mesh:
            continue
        if c.get("status") == "skipped":
            rows.append(f"{c['arch']:28s} {c['shape']:12s} "
                        f"{'N/A (' + c['reason'][:48] + ')'}")
            continue
        if c.get("status") != "ok":
            rows.append(f"{c['arch']:28s} {c['shape']:12s} ERROR")
            continue
        r = c["roofline"]
        terms = {k: r[k + "_s"] for k in ("compute", "memory", "collective")}
        frac = terms["compute"] / max(max(terms.values()), 1e-30)
        rows.append(
            f"{c['arch']:28s} {c['shape']:12s} "
            f"{terms['compute']*1e3:9.1f}ms {terms['memory']*1e3:9.1f}ms "
            f"{terms['collective']*1e3:9.1f}ms {r['dominant']:>10s} "
            f"{c['useful_compute_ratio']:7.3f} {frac:6.3f}")
    return rows


def run():
    out = []
    for c in load():
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append(
            f"{name},{total*1e6:.1f},dominant={r['dominant']} "
            f"compute_ms={r['compute_s']*1e3:.1f} "
            f"memory_ms={r['memory_s']*1e3:.1f} "
            f"collective_ms={r['collective_s']*1e3:.1f} "
            f"useful={c['useful_compute_ratio']:.3f}")
    return out


if __name__ == "__main__":
    for row in table():
        print(row)
