"""Roofline report: aggregates experiments/dryrun/*.json into the §Roofline
table (per arch × shape × mesh: three terms, dominant bottleneck, MODEL_FLOPS
ratio). Also hosts the power-engine SWEEP-COUNT model (DESIGN.md §7): the
closed-form HBM-traffic-per-iteration accounting that bench_multivec.py and
``benchmarks.run --json`` report against."""
from __future__ import annotations

import glob
import json
import os


def sweep_model(n: int, r: int, mode: str, *, m: int = 2, a_bytes: int = 4,
                tm: int = 256, tn: int = 256) -> dict:
    """HBM traffic per power iteration for ``r`` vectors on n points.

    Modes (DESIGN.md §7):
      seed_pervec       r independent matvec loops: r full sweeps of A.
      engine_explicit   batched (n, r) mat-mat: ONE sweep of A, amortized
                        over all r vectors (A may be bf16: a_bytes=2).
      engine_streaming  A never stored: per (i, j) tile step the kernel
                        re-reads a (tm, m) + (tn, m) feature slab — slab
                        traffic is independent of a_bytes and r, with NO
                        O(n^2) residency.

    All tiled modes also re-fetch the (tn, r) V slice per grid step (each
    of the n/tm output row-blocks scans the full V) and write U once:
    4·n·r·(n/tm + 1) bytes — identical across modes, so the A-traffic term
    is what separates them.
    """
    vec_bytes = 4 * n * r * (n // tm) + 4 * n * r  # V re-reads + U write, f32
    if mode == "seed_pervec":
        a_traffic, sweeps = r * n * n * a_bytes, r
    elif mode == "engine_explicit":
        a_traffic, sweeps = n * n * a_bytes, 1
    elif mode == "engine_streaming":
        a_traffic = 4 * n * m * (n // tn + n // tm)    # f32 slabs, re-read per tile row/col
        sweeps = 0
    else:
        raise ValueError(f"unknown sweep mode {mode!r}")
    return {
        "mode": mode, "n": n, "r": r, "a_sweeps": sweeps,
        "bytes_per_iter": a_traffic + vec_bytes,
        "a_bytes_resident": 0 if mode == "engine_streaming" else n * n * a_bytes,
    }


def load(dryrun_dir="experiments/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(dryrun_dir="experiments/dryrun", mesh="16x16"):
    rows = []
    hdr = (f"{'arch':28s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collective':>10s} {'dominant':>10s} {'useful':>7s} {'frac':>6s}")
    rows.append(hdr)
    for c in load(dryrun_dir):
        if c.get("mesh") != mesh:
            continue
        if c.get("status") == "skipped":
            rows.append(f"{c['arch']:28s} {c['shape']:12s} "
                        f"{'N/A (' + c['reason'][:48] + ')'}")
            continue
        if c.get("status") != "ok":
            rows.append(f"{c['arch']:28s} {c['shape']:12s} ERROR")
            continue
        r = c["roofline"]
        terms = {k: r[k + "_s"] for k in ("compute", "memory", "collective")}
        frac = terms["compute"] / max(max(terms.values()), 1e-30)
        rows.append(
            f"{c['arch']:28s} {c['shape']:12s} "
            f"{terms['compute']*1e3:9.1f}ms {terms['memory']*1e3:9.1f}ms "
            f"{terms['collective']*1e3:9.1f}ms {r['dominant']:>10s} "
            f"{c['useful_compute_ratio']:7.3f} {frac:6.3f}")
    return rows


def run():
    out = []
    for c in load():
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append(
            f"{name},{total*1e6:.1f},dominant={r['dominant']} "
            f"compute_ms={r['compute_s']*1e3:.1f} "
            f"memory_ms={r['memory_s']*1e3:.1f} "
            f"collective_ms={r['collective_s']*1e3:.1f} "
            f"useful={c['useful_compute_ratio']:.3f}")
    return out


if __name__ == "__main__":
    for row in table():
        print(row)
