"""Embedding-quality + QR-cost benchmark (ISSUE 3 acceptance evidence).

Two sections:

  quality/<dataset>/<mode>   end-to-end ``run_gpic`` wall time with the ARI
                             against ground truth in the derived column —
                             the per-dataset-per-mode quality table
                             (DESIGN.md §10) as a tracked snapshot row.
  quality/qr_cost/r=<r>      wall time of ONE pinned Cholesky-QR step
                             (Pallas Gram kernel + factor + solve) on the
                             (n, r) block at r ∈ {1, 4, 8}, with the cost
                             of one explicit A-sweep alongside — the ratio
                             is the per-sweep overhead the orthogonal mode
                             pays at qr_every=1 (O(n r²) against O(n² r)).

Run:  PYTHONPATH=src python -m benchmarks.run --only quality
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    GPICConfig,
    adjusted_rand_index,
    orthonormalize_block,
    run_gpic,
)
from repro.core.affinity import row_normalize_features
from repro.core.operators import explicit_operator
from repro.data import anisotropic, gaussians, three_circles, two_moons
from repro.kernels import ops

from .common import csv_row, time_fn

#: the quality-suite scenario matrix (thresholds asserted in
#: tests/test_embedding_quality.py; this records the measured values)
DATASETS = (
    ("blobs", gaussians, 4, 0.3),
    ("moons", two_moons, 2, 0.25),
    ("three_circles", three_circles, 3, 0.3),
    ("anisotropic", anisotropic, 3, 0.3),
)
MODES = (("pic", 1), ("orthogonal", 2), ("ensemble", 1))


def run(n=480, max_iter=400, qr_n=1024):
    rows = []

    # --- ARI per dataset per embedding mode ------------------------------
    for name, gen, k, sigma in DATASETS:
        x, y = gen(n, seed=0)
        xj = jnp.asarray(x)
        for mode, r in MODES:
            cfg = GPICConfig(affinity_kind="rbf", sigma=sigma,
                             max_iter=max_iter, n_vectors=r, embedding=mode)
            t, res = time_fn(run_gpic, xj, k, cfg, key=jax.random.key(1))
            ari = adjusted_rand_index(y, np.asarray(res.labels))
            rows.append(csv_row(
                f"quality/{name}/{mode}", t,
                f"ari={ari:.3f} r={r} n_iter={int(res.n_iter)}"))

    # --- per-sweep QR cost at r in {1, 4, 8} -----------------------------
    x, _ = gaussians(qr_n, seed=0)
    xn = row_normalize_features(jnp.asarray(x))
    op = explicit_operator(xn, kind="cosine_shifted")
    for r in (1, 4, 8):
        v = jax.random.uniform(jax.random.key(r), (qr_n, r))
        v = v / jnp.sum(jnp.abs(v), axis=0, keepdims=True)
        qr_step = jax.jit(lambda vv: orthonormalize_block(op, vv))
        t_qr, _ = time_fn(qr_step, v)
        t_sweep, _ = time_fn(jax.jit(op.matmat), v)
        rows.append(csv_row(
            f"quality/qr_cost/r={r}", t_qr,
            f"sweep_us={t_sweep * 1e6:.1f} "
            f"qr_over_sweep={t_qr / max(t_sweep, 1e-12):.3f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
