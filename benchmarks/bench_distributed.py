"""Distributed pipeline benchmark — per-path sweep timings on a host mesh.

Times each sharded path (explicit stripes, streaming ring, matrix-free)
against its single-device counterpart on an 8-virtual-device CPU mesh.
The power loop is pinned to exact sweep counts (eps unreachably low), and
each path is timed at TWO counts — ``iters`` and ``2*iters`` — so the
reported per-sweep cost is the difference quotient: one-time cost
(affinity build, k-means) cancels out and the tracked number is the cost
of one sweep, per path, not build amortization or convergence luck. The
one-time residual is reported as a separate ``setup`` row. On CPU
interpret mode the absolute numbers are structural only (python per grid
step) — compare ratios between paths and across snapshots.

The measurement runs in a subprocess (XLA_FLAGS must set the device count
before jax imports; the parent benchmark process keeps its single-device
view), which prints finished CSV rows on stdout.

Run:  PYTHONPATH=src python -m benchmarks.run --only distributed
"""
from __future__ import annotations

from repro.testing import run_mesh_subprocess

_SCRIPT = """
    import time
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import GPICConfig, run_gpic
    from repro.core.distributed import shard_points
    from repro.data.synthetic import gaussians

    n, r, iters = {n}, {r}, {iters}
    mesh = jax.make_mesh((8,), ("data",))
    x, _ = gaussians(n, k=3, seed=0)
    xs = shard_points(x, mesh, "data")
    xl = jnp.asarray(x)

    def timed(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)           # compile + warm
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2]

    # eps_scale ~0 => the loop never converges: exact sweep counts per run.
    # Timing at iters and 2*iters cancels one-time cost (build, k-means)
    # out of the difference quotient.
    base = GPICConfig(affinity_kind="cosine_shifted", n_vectors=r,
                      eps_scale=1e-300, kmeans_iters=5)
    key = jax.random.key(0)

    def per_sweep(x_in, cfg):
        t1 = timed(lambda c: run_gpic(x_in, 3, c, key=key),
                   cfg.with_(max_iter=iters))
        t2 = timed(lambda c: run_gpic(x_in, 3, c, key=key),
                   cfg.with_(max_iter=2 * iters))
        sweep = max(t2 - t1, 1e-9) / iters
        setup = max(t1 - sweep * iters, 0.0)
        return sweep, setup

    for path in ("explicit", "streaming", "matrix_free"):
        cfg = base.with_(engine=path)
        sweep_sd, setup_sd = per_sweep(xl, cfg)
        sweep_ds, setup_ds = per_sweep(xs, cfg.with_(mesh=mesh))
        print(f"distributed/{{path}}/single_device,{{sweep_sd*1e6:.1f}},"
              f"n={{n}} r={{r}} per_sweep setup_us={{setup_sd*1e6:.1f}}")
        print(f"distributed/{{path}}/mesh8,{{sweep_ds*1e6:.1f}},"
              f"n={{n}} r={{r}} per_sweep setup_us={{setup_ds*1e6:.1f}} "
              f"ratio_vs_single={{sweep_ds/sweep_sd:.2f}}x")
    """


def run(n: int = 1024, r: int = 4, iters: int = 5):
    """Returns CSV rows (per-path sweep timings, single-device vs mesh)."""
    out = run_mesh_subprocess(_SCRIPT.format(n=n, r=r, iters=iters),
                              timeout=1800)
    return [ln for ln in out.splitlines()
            if ln.startswith("distributed/")]


if __name__ == "__main__":
    for row in run():
        print(row)
