"""Robustness subsystem benchmark (ISSUE 6 acceptance evidence).

Sections:

  robustness/guard_overhead/r=<r>   the one convergence loop compiled with
                                    the divergence latches armed
                                    (collect_health=True, the shipping
                                    configuration) vs compiled without
                                    them — ASSERTS the armed loop costs at
                                    most GUARD_BUDGET_PCT more (the
                                    latches are O(r) epilogue work against
                                    an O(n²/P) sweep, and on a clean run
                                    every predicate is False so the
                                    results are bitwise identical — also
                                    asserted)
  robustness/frontdoor              host-side validate_features cost on a
                                    clean feature matrix (what every
                                    run_gpic call now pays at the door)
  robustness/probe/knn              end-to-end run_gpic on a kNN graph
                                    with the component probe on vs off —
                                    the probe's extra reachability sweeps,
                                    priced
  robustness/fault/<class>          the fault matrix, one row per class:
                                    each degenerate input must resolve to
                                    its contracted outcome (typed error or
                                    degraded-with-health) — ASSERTED, so a
                                    regression that lets garbage escape
                                    fails the benchmark run, not just the
                                    test suite
  robustness/checkpoint_overhead    the resumable supervisor with async
                                    snapshots every 25 sweeps vs the
                                    monolithic run (PR 9) — ASSERTS the
                                    supervised run costs at most
                                    CHECKPOINT_BUDGET_PCT more and returns
                                    the monolithic labels bitwise (same
                                    paired-interleaved timing as the guard
                                    rows)

Run:  PYTHONPATH=src python -m benchmarks.run --only robustness
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AffinitySpec,
    DegenerateGraphError,
    GPICConfig,
    GPICError,
    NonFiniteInputError,
    batched_power_iteration,
    explicit_operator,
    run_gpic,
)
from repro.core.affinity import as_affinity_spec, row_normalize_features
from repro.core.health import validate_features
from repro.kernels import ops

from .common import csv_row, time_fn

#: guard-overhead acceptance ceiling, percent (ISSUE 6)
GUARD_BUDGET_PCT = 2.0

#: resumable-supervisor overhead ceiling at checkpoint_every=25, percent
#: (ISSUE 9: segments + async snapshots against the monolithic loop)
CHECKPOINT_BUDGET_PCT = 5.0


def _paired_overhead_pct(fn_on, fn_off, v0, *, pairs=11):
    """Median percent slowdown of fn_on over fn_off from INTERLEAVED pairs.

    A plain median-of-repeats difference of two ~300 ms walls drowns a
    sub-1% effect in scheduler drift (both signs of 5% swings observed on
    this host); running the two compiled loops back-to-back per pair and
    taking the median of per-pair ratios cancels the drift common to the
    pair.
    """
    import time as _time

    jax.block_until_ready(fn_on(v0))
    jax.block_until_ready(fn_off(v0))
    diffs = []
    for _ in range(pairs):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn_on(v0))
        on = _time.perf_counter() - t0
        t0 = _time.perf_counter()
        jax.block_until_ready(fn_off(v0))
        off = _time.perf_counter() - t0
        diffs.append((100.0 * (on - off) / off, on, off))
    diffs.sort()
    return diffs[len(diffs) // 2]


def _guard_overhead_rows(n, rows):
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, 16)),
                    jnp.float32)
    spec = as_affinity_spec(None, kind="cosine_shifted")
    op = explicit_operator(row_normalize_features(x), spec=spec)
    for r in (1, 4):
        v0 = jax.random.uniform(jax.random.key(r), (n, r)) + 0.5
        v0 = v0 / jnp.sum(jnp.abs(v0), axis=0)

        def jitted(collect):
            return jax.jit(functools.partial(
                batched_power_iteration, op, eps=1e-5 / n, max_iter=30,
                collect_health=collect))

        loop_on, loop_off = jitted(True), jitted(False)
        np.testing.assert_array_equal(
            np.asarray(loop_on(v0)[0]), np.asarray(loop_off(v0)[0]),
            err_msg="the latches changed a clean run (must be bitwise "
                    "pure observers)")
        # best-of-3 measurement rounds: the true effect is <1%, so a round
        # that lands over budget means external load skewed even the
        # paired medians — retry rather than fail on a contended host
        for attempt in range(3):
            pct, t_on, t_off = _paired_overhead_pct(loop_on, loop_off, v0)
            if pct <= GUARD_BUDGET_PCT:
                break
        assert pct <= GUARD_BUDGET_PCT, (
            f"divergence latches cost {pct:.2f}% at r={r} "
            f"(budget {GUARD_BUDGET_PCT}%): {t_on * 1e6:.0f}us vs "
            f"{t_off * 1e6:.0f}us")
        rows.append(csv_row(
            f"robustness/guard_overhead/r={r}", t_on,
            f"base_us={t_off * 1e6:.1f} overhead_pct={pct:.2f} "
            f"budget_pct={GUARD_BUDGET_PCT} bitwise=1"))


def _frontdoor_row(n, rows):
    x = jnp.asarray(np.random.default_rng(1).normal(size=(n, 16)),
                    jnp.float32)
    t, _ = time_fn(validate_features, x, 4, repeats=5)
    rows.append(csv_row("robustness/frontdoor", t, f"n={n} m=16"))


def _probe_rows(n, rows):
    x = np.random.default_rng(2).normal(size=(n, 2)).astype(np.float32)
    spec = AffinitySpec(kind="rbf", sigma=1.0, knn_k=16)
    cfg = GPICConfig(affinity=spec)
    key = jax.random.key(0)
    t_on, res = time_fn(run_gpic, x, 3, cfg, key=key)
    t_off, _ = time_fn(run_gpic, x, 3, cfg.with_(component_probe=False),
                       key=key)
    rows.append(csv_row(
        "robustness/probe/knn", t_on,
        f"base_us={t_off * 1e6:.1f} "
        f"n_components={int(res.health.n_components)}"))


def _fault_matrix_rows(n, rows):
    rs = np.random.RandomState(0)
    blobs = np.concatenate([
        rs.randn(n // 2, 2).astype(np.float32) * 0.2,
        rs.randn(n // 2, 2).astype(np.float32) * 0.2 + 8.0])

    def nan_features():
        bad = blobs.copy()
        bad[3] = np.nan
        run_gpic(bad, 2)

    def isolated_row():
        x = np.concatenate([blobs[:-1],
                            np.full((1, 2), 500.0, np.float32)])
        res = run_gpic(x, 2, GPICConfig(affinity_kind="rbf", sigma=0.5))
        assert int(res.health.isolated_rows) == 1
        assert np.isfinite(np.asarray(res.embedding)).all()
        return "degraded:isolated_rows=1"

    def disconnected():
        spec = AffinitySpec(kind="rbf", sigma=0.5, knn_k=8)
        res = run_gpic(blobs, 2, GPICConfig(affinity=spec))
        assert int(res.health.n_components) == 2
        return "degraded:n_components=2"

    def all_isolated():
        x = (np.random.RandomState(2).randn(24, 3) * 1e4).astype(np.float32)
        run_gpic(x, 3, GPICConfig(affinity_kind="rbf", sigma=1e-3))

    def kernel_failure():
        ops.reset_kernel_fallbacks()
        jax.clear_caches()
        try:
            with ops.forced_kernel_failure("gram"):
                res = run_gpic(blobs, 2, GPICConfig(embedding="orthogonal",
                                                    n_vectors=2))
            assert "kernel_fallback:gram" in res.health.notes
            return "degraded:kernel_fallback=gram"
        finally:
            ops.reset_kernel_fallbacks()
            jax.clear_caches()

    matrix = (
        ("nonfinite_features", nan_features, NonFiniteInputError),
        ("isolated_row", isolated_row, None),
        ("disconnected_knn", disconnected, None),
        ("all_rows_isolated", all_isolated, DegenerateGraphError),
        ("forced_kernel_failure", kernel_failure, None),
    )
    for tag, fn, want_exc in matrix:
        def trial(fn=fn, want_exc=want_exc):
            try:
                out = fn()
            except GPICError as e:
                assert want_exc is not None and isinstance(e, want_exc), (
                    f"unexpected {type(e).__name__}: {e}")
                return f"typed_error:{type(e).__name__}"
            assert want_exc is None, f"expected {want_exc.__name__}"
            return out
        t, outcome = time_fn(trial, warmup=1, repeats=3)
        rows.append(csv_row(f"robustness/fault/{tag}", t, outcome))


def _checkpoint_overhead_rows(n, rows):
    """Price the PR-9 resumable supervisor: segmented sweeps + async
    snapshots every 25 sweeps vs the monolithic run_gpic call. eps_scale
    pins the loop at max_iter so both paths run the same 50 sweeps and
    the supervised path crosses a snapshot boundary."""
    import os
    import shutil
    import tempfile

    x = jnp.asarray(np.random.default_rng(3).normal(size=(n, 2)),
                    jnp.float32)
    root = tempfile.mkdtemp(prefix="gpic_ckpt_bench_")
    cfg = GPICConfig(max_iter=50, eps_scale=1e-9)
    ck = cfg.with_(checkpoint_every=25, ckpt_dir=os.path.join(root, "ck"))

    def run_plain(_):
        return run_gpic(x, 3, cfg).labels

    def run_ckpt(_):
        # a fresh dir per call: stale snapshots would short-circuit the
        # loop via resume and time only the finalize
        shutil.rmtree(ck.ckpt_dir, ignore_errors=True)
        return run_gpic(x, 3, ck).labels

    try:
        np.testing.assert_array_equal(
            np.asarray(run_ckpt(None)), np.asarray(run_plain(None)),
            err_msg="supervised run diverged from the monolithic labels "
                    "(resume parity must be bitwise)")
        for attempt in range(3):
            pct, t_on, t_off = _paired_overhead_pct(run_ckpt, run_plain,
                                                    None)
            if pct <= CHECKPOINT_BUDGET_PCT:
                break
        assert pct <= CHECKPOINT_BUDGET_PCT, (
            f"checkpointing every 25 sweeps costs {pct:.2f}% "
            f"(budget {CHECKPOINT_BUDGET_PCT}%): {t_on * 1e6:.0f}us vs "
            f"{t_off * 1e6:.0f}us")
        rows.append(csv_row(
            "robustness/checkpoint_overhead/every=25", t_on,
            f"base_us={t_off * 1e6:.1f} overhead_pct={pct:.2f} "
            f"budget_pct={CHECKPOINT_BUDGET_PCT} bitwise=1"))
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(n=2048, fault_n=256):
    rows = []
    _guard_overhead_rows(n, rows)
    _frontdoor_row(n, rows)
    _probe_rows(fault_n, rows)
    _fault_matrix_rows(fault_n, rows)
    _checkpoint_overhead_rows(fault_n * 4, rows)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
