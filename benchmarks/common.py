"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup=1, repeats=3, **kw):
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
