"""Paper Table 2: runtime + speedup of GPIC vs serial PIC (and the parallel
baseline).

Mapping onto this container (CPU; TPU is the compile target):
  "PIC serial"    -> pic_serial_numpy (row-loop numpy, the MATLAB stand-in)
  "GPIC"          -> gpic() jit-compiled fused pipeline (XLA; the same fused
                     program the Pallas kernels implement on TPU)
  "GPIC-MF"       -> gpic_matrix_free() — beyond-paper O2 path
Parameters follow the paper: max_iter=3, eps=1e-5/n, cosine similarity, m=2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gpic, gpic_matrix_free, pic_serial_numpy
from repro.data import three_circles, two_moons

from .common import csv_row, time_fn


def run(sizes=(1000, 2000, 4000), max_iter=3):
    rows = []
    key = jax.random.key(0)
    for name, gen, k in (("two_moons", two_moons, 2),
                         ("three_circles", three_circles, 3)):
        xw, _ = gen(64, seed=0)
        pic_serial_numpy(xw, k, affinity_kind="cosine_shifted", max_iter=2)
        for n in sizes:
            x, _ = gen(n, seed=0)
            xj = jnp.asarray(x)

            _, _, tm = pic_serial_numpy(x, k, affinity_kind="cosine_shifted",
                                        max_iter=max_iter,
                                        return_timings=True)
            t_serial = tm["total_s"]

            t_gpic, _ = time_fn(
                lambda: gpic(xj, k, key=key, affinity_kind="cosine_shifted",
                             max_iter=max_iter, use_pallas=False))
            t_mf, _ = time_fn(
                lambda: gpic_matrix_free(xj, k, key=key,
                                         affinity_kind="cosine_shifted",
                                         max_iter=max_iter))
            # engine rows: streaming (A-free) and multi-vector batched state
            # (same jnp reference ops as the gpic row — apples to apples)
            t_stream, _ = time_fn(
                lambda: gpic(xj, k, key=key, affinity_kind="cosine_shifted",
                             max_iter=max_iter, use_pallas=False,
                             engine="streaming"))
            t_mv4, _ = time_fn(
                lambda: gpic(xj, k, key=key, affinity_kind="cosine_shifted",
                             max_iter=max_iter, use_pallas=False,
                             n_vectors=4))

            rows.append(csv_row(f"table2/{name}/n={n}/serial", t_serial, ""))
            rows.append(csv_row(f"table2/{name}/n={n}/gpic", t_gpic,
                                f"speedup={t_serial / t_gpic:.1f}x"))
            rows.append(csv_row(f"table2/{name}/n={n}/gpic_mf", t_mf,
                                f"speedup={t_serial / t_mf:.1f}x"))
            rows.append(csv_row(f"table2/{name}/n={n}/gpic_stream", t_stream,
                                f"speedup={t_serial / t_stream:.1f}x"))
            rows.append(csv_row(f"table2/{name}/n={n}/gpic_r4", t_mv4,
                                f"speedup={t_serial / t_mv4:.1f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
