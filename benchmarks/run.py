"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # quick set
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,table2,fig3,exp2,"
                         "roofline")
    args = ap.parse_args()

    from . import bench_exp2, bench_fig3, bench_table1, bench_table2, roofline

    jobs = {
        "table1": lambda: bench_table1.run(
            sizes=(1000, 2000, 4000, 8000) if args.full else (1000, 2000)),
        "table2": lambda: bench_table2.run(
            sizes=(1000, 2000, 4000, 8000) if args.full else (1000, 2000)),
        "fig3": lambda: bench_fig3.run(),
        "exp2": lambda: bench_exp2.run(
            n=45_000 if args.full else 9_000,
            repeats=10 if args.full else 2,
            fractions=((0.002, 0.005, 0.01, 0.02, 0.05, 0.1) if args.full
                       else (0.01, 0.05, 0.2))),
        "roofline": roofline.run,
    }
    selected = (args.only.split(",") if args.only else list(jobs))

    print("name,us_per_call,derived")
    for name in selected:
        try:
            for row in jobs[name]():
                print(row, flush=True)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
