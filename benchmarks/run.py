"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # quick set
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
    PYTHONPATH=src python -m benchmarks.run --json     # + BENCH_PR9.json

``--json [PATH]`` additionally writes a machine-readable perf snapshot
(us/call per job row plus the engine sweep-count model) for CI diffing.
"""
from __future__ import annotations

import argparse
import json
import sys


def _rows_to_records(rows):
    recs = []
    for row in rows:
        name, us, *derived = row.split(",", 2)
        recs.append({
            "name": name,
            "us_per_call": float(us),
            "derived": derived[0] if derived else "",
        })
    return recs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,table2,fig3,exp2,"
                         "roofline,multivec,distributed,quality,affinity,"
                         "robustness")
    ap.add_argument("--json", nargs="?", const="BENCH_PR9.json", default=None,
                    metavar="PATH",
                    help="write a JSON perf snapshot (default BENCH_PR9.json)")
    args = ap.parse_args()

    from . import (bench_affinity, bench_distributed, bench_exp2, bench_fig3,
                   bench_multivec, bench_quality, bench_robustness,
                   bench_table1, bench_table2, roofline)

    jobs = {
        "table1": lambda: bench_table1.run(
            sizes=(1000, 2000, 4000, 8000) if args.full else (1000, 2000)),
        "table2": lambda: bench_table2.run(
            sizes=(1000, 2000, 4000, 8000) if args.full else (1000, 2000)),
        "fig3": lambda: bench_fig3.run(),
        "exp2": lambda: bench_exp2.run(
            n=45_000 if args.full else 9_000,
            repeats=10 if args.full else 2,
            fractions=((0.002, 0.005, 0.01, 0.02, 0.05, 0.1) if args.full
                       else (0.01, 0.05, 0.2))),
        "roofline": roofline.run,
        "multivec": lambda: bench_multivec.run(
            n=2048 if args.full else 1024),
        "distributed": lambda: bench_distributed.run(
            n=2048 if args.full else 1024),
        # the quality section: per-dataset ARI for every embedding mode +
        # per-sweep QR cost at r in {1, 4, 8} (tracked across snapshots)
        "quality": lambda: bench_quality.run(
            n=960 if args.full else 480,
            qr_n=2048 if args.full else 1024),
        # the affinity-graph subsystem: two-pass build + sweep cost dense
        # vs truncated, the two_moons kNN acceptance, and the subspace
        # residual stopping rule (reduction asserted on every run)
        "affinity": lambda: bench_affinity.run(
            n=2048 if args.full else 1024,
            moons_n=960 if args.full else 480),
        # the robustness subsystem: divergence-latch overhead vs the
        # latch-free loop (budget asserted; fixed n — at 4096 the 5 s
        # interpret-mode walls drown the sub-1% effect in timer noise),
        # front-door validation cost, component-probe cost, and the fault
        # matrix (every degenerate input must resolve to its contracted
        # outcome — asserted)
        "robustness": lambda: bench_robustness.run(n=2048),
    }
    selected = (args.only.split(",") if args.only else list(jobs))

    snapshot = {"jobs": {}, "sweep_model": []}
    print("name,us_per_call,derived")
    for name in selected:
        try:
            rows = jobs[name]()
            for row in rows:
                print(row, flush=True)
            if args.json:
                # jobs["distributed"] is the per-path sweep-timing section
                # tracked across PR snapshots
                snapshot["jobs"][name] = _rows_to_records(rows)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", file=sys.stderr)
            raise

    if args.json:
        n = 2048 if args.full else 1024
        for mode in ("seed_pervec", "engine_explicit", "engine_streaming"):
            for r in (1, 4):
                snapshot["sweep_model"].append(roofline.sweep_model(n, r, mode))
        with open(args.json, "w") as f:
            json.dump(snapshot, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
