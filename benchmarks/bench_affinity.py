"""Affinity-graph subsystem benchmark (ISSUE 5 acceptance evidence).

Sections (all at r ∈ {1, 4} where a sweep is involved):

  affinity/build/<spec>          two-pass graph build wall time (pass-1
                                 row-top-k statistics + masked A+D build)
                                 for the dense, kNN, and adaptive+kNN specs
                                 — the 88.6 %-of-runtime stage (PAPER §4.2)
  affinity/sweep/<spec>/r=<r>    ONE explicit degree-normalized sweep on
                                 the built graph; the derived column
                                 records nnz/row — dense storage keeps the
                                 sweep cost flat, the recorded sparsity is
                                 the headroom a sparse format unlocks on
                                 real TPU (ROADMAP follow-up)
  affinity/moons/<spec>          end-to-end run_gpic on two_moons(480) at
                                 sigma 0.25: ARI + sweep count — the
                                 quality acceptance (dense ~0.5, kNN 1.0)
  affinity/sparse/*              block-CSR storage (ISSUE 8): on
                                 cluster-sorted blobs the kNN mask kills
                                 whole (128, 128) tiles; ``dense_storage=0``
                                 rows time the SAME truncated graph through
                                 the stripe-tile plan. ASSERTS the knn30
                                 r=1 sparse sweep is >= 2x faster than the
                                 dense-storage sweep and the fused one-pass
                                 build lands within 2x of the dense build
  affinity/residual_stop         orthogonal mode on three_circles with and
                                 without residual_tol; ASSERTS the
                                 sweep-count reduction (the ROADMAP
                                 stopping-rule item) and the bitwise pin
                                 of column 0 on every run

Run:  PYTHONPATH=src python -m benchmarks.run --only affinity
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AffinitySpec,
    GPICConfig,
    adjusted_rand_index,
    run_gpic,
)
from repro.core.affinity import block_plan, dense_block_live
from repro.core.graph import affinity_stats, fused_affinity_build
from repro.data import three_circles, two_moons
from repro.kernels import ops

from .common import csv_row, time_fn

SPECS = (
    ("dense", AffinitySpec(kind="rbf", sigma=0.25)),
    ("knn30", AffinitySpec(kind="rbf", sigma=0.25, knn_k=30)),
    ("ad+knn10", AffinitySpec(kind="rbf", bandwidth="adaptive",
                              scale_k=7, knn_k=10)),
)


def _build(x, spec):
    scale, thr = affinity_stats(x, spec)
    return ops.affinity_and_degree(x, spec=spec, scale_r=scale,
                                   scale_c=scale, thr=thr)


def run(n=1024, moons_n=480, max_iter=400):
    rows = []
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, 2)),
                    jnp.float32)

    # --- two-pass build + per-sweep cost, dense vs truncated -------------
    built = {}
    for tag, spec in SPECS:
        t_build, (a, d) = time_fn(_build, x, spec)
        nnz = float((np.asarray(a) != 0.0).sum(axis=1).mean())
        built[tag] = (a, d)
        rows.append(csv_row(f"affinity/build/{tag}", t_build,
                            f"n={n} nnz_per_row={nnz:.1f}"))
        for r in (1, 4):
            v = jax.random.uniform(jax.random.key(r), (n, r))
            t_sweep, _ = time_fn(
                lambda a=a, v=v, d=d: ops.degree_normalized_matmat(a, v, d))
            rows.append(csv_row(
                f"affinity/sweep/{tag}/r={r}", t_sweep,
                f"nnz_frac={nnz / n:.3f} dense_storage=1"))

    # --- block-CSR storage: fused one-pass build + stripe-tile sweeps ----
    # cluster-sorted blobs so truncation produces DEAD TILES (the random
    # cloud above keeps every tile live — it measures mask overhead, not
    # storage); tile=128 on 8 blobs of n/8 points each
    rng = np.random.default_rng(0)
    n_blobs, tile_s = 8, 128
    centers = rng.uniform(-20.0, 20.0, (n_blobs, 2))
    xb = jnp.asarray(np.concatenate([
        centers[i] + 0.5 * rng.standard_normal((n // n_blobs, 2))
        for i in range(n_blobs)
    ]), jnp.float32)
    dense_spec = SPECS[0][1]
    # jit the whole build on both sides: the operators always run these
    # inside the gpic jit, and eager per-op dispatch would swamp the
    # epilogue arithmetic being measured
    t_dense_build, _ = time_fn(jax.jit(
        lambda xb: _build(xb, dense_spec)), xb)
    rows.append(csv_row("affinity/sparse/build/dense", t_dense_build,
                        f"n={n} tile={tile_s}"))
    for tag, spec in SPECS[1:]:
        scale = affinity_stats(xb, spec)[0] if spec.adaptive else None
        t_fused, (a, d, _thr) = time_fn(jax.jit(
            lambda xb, sc, s=spec: fused_affinity_build(
                xb, spec=s, scale_r=sc, scale_c=sc, tm=tile_s,
                tn=tile_s)), xb, scale)
        counts, col_idx, max_b = block_plan(dense_block_live(a, tile_s,
                                                             tile_s))
        live_frac = float(np.asarray(counts).sum()) / counts.shape[0] \
            / (-(-n // tile_s))
        rows.append(csv_row(
            f"affinity/sparse/build/{tag}", t_fused,
            f"one_pass=1 live_block_frac={live_frac:.3f} "
            f"vs_dense_build_x={t_fused / t_dense_build:.2f}"))
        for r in (1, 4):
            v = jax.random.uniform(jax.random.key(r), (n, r))
            t_dn, _ = time_fn(
                lambda v=v, a=a, d=d: ops.degree_normalized_matmat(
                    a, v, d, tm=tile_s, tn=tile_s))
            t_bs, _ = time_fn(
                lambda v=v, a=a, d=d: ops.block_sparse_matmat(
                    a, v, d, counts, col_idx, max_b, tm=tile_s, tn=tile_s))
            rows.append(csv_row(
                f"affinity/sparse/sweep/{tag}/r={r}", t_bs,
                f"dense_storage=0 dense_storage_us={t_dn * 1e6:.1f} "
                f"speedup_x={t_dn / t_bs:.2f}"))
            if tag == "knn30" and r == 1:
                assert t_bs * 2.0 <= t_dn, (
                    f"block-sparse sweep not >=2x faster: {t_bs * 1e6:.0f}us"
                    f" vs dense-storage {t_dn * 1e6:.0f}us")
        if tag == "knn30":
            assert t_fused <= 2.0 * t_dense_build, (
                f"fused one-pass build {t_fused * 1e6:.0f}us exceeds 2x the "
                f"dense build {t_dense_build * 1e6:.0f}us")

    # --- quality: the two_moons acceptance -------------------------------
    xm, ym = two_moons(moons_n, seed=0)
    xmj = jnp.asarray(xm)
    for tag, spec in SPECS:
        cfg = GPICConfig(affinity=spec, max_iter=max_iter, n_vectors=2,
                         embedding="orthogonal")
        t, res = time_fn(run_gpic, xmj, 2, cfg, key=jax.random.key(1))
        ari = adjusted_rand_index(ym, np.asarray(res.labels))
        rows.append(csv_row(
            f"affinity/moons/{tag}", t,
            f"ari={ari:.3f} n_iter={int(res.n_iter)} "
            f"iters={np.asarray(res.n_iter_cols).tolist()}"))

    # --- the subspace residual stopping rule (assert the reduction) ------
    xc, yc = three_circles(moons_n, seed=0)
    xcj = jnp.asarray(xc)
    base = GPICConfig(affinity_kind="rbf", sigma=0.3, max_iter=max_iter,
                      n_vectors=2, embedding="orthogonal")
    t_full, full = time_fn(run_gpic, xcj, 3, base, key=jax.random.key(1))
    t_res, res = time_fn(run_gpic, xcj, 3, base.with_(residual_tol=1e-3),
                         key=jax.random.key(1))
    sweeps_full = int(np.asarray(full.n_iter_cols).max())
    sweeps_res = int(np.asarray(res.n_iter_cols).max())
    assert sweeps_res < sweeps_full, (
        f"residual stopping did not reduce sweeps: {sweeps_res} vs "
        f"{sweeps_full}")
    np.testing.assert_array_equal(
        np.asarray(res.embedding), np.asarray(full.embedding),
        err_msg="residual stopping perturbed the pinned column-0 trajectory")
    ari_res = adjusted_rand_index(yc, np.asarray(res.labels))
    rows.append(csv_row(
        "affinity/residual_stop", t_res,
        f"sweeps={sweeps_res} vs_max_iter={sweeps_full} ari={ari_res:.3f} "
        f"col0_bitwise=1"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
