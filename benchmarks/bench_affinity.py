"""Affinity-graph subsystem benchmark (ISSUE 5 acceptance evidence).

Sections (all at r ∈ {1, 4} where a sweep is involved):

  affinity/build/<spec>          two-pass graph build wall time (pass-1
                                 row-top-k statistics + masked A+D build)
                                 for the dense, kNN, and adaptive+kNN specs
                                 — the 88.6 %-of-runtime stage (PAPER §4.2)
  affinity/sweep/<spec>/r=<r>    ONE explicit degree-normalized sweep on
                                 the built graph; the derived column
                                 records nnz/row — dense storage keeps the
                                 sweep cost flat, the recorded sparsity is
                                 the headroom a sparse format unlocks on
                                 real TPU (ROADMAP follow-up)
  affinity/moons/<spec>          end-to-end run_gpic on two_moons(480) at
                                 sigma 0.25: ARI + sweep count — the
                                 quality acceptance (dense ~0.5, kNN 1.0)
  affinity/residual_stop         orthogonal mode on three_circles with and
                                 without residual_tol; ASSERTS the
                                 sweep-count reduction (the ROADMAP
                                 stopping-rule item) and the bitwise pin
                                 of column 0 on every run

Run:  PYTHONPATH=src python -m benchmarks.run --only affinity
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AffinitySpec,
    GPICConfig,
    adjusted_rand_index,
    run_gpic,
)
from repro.core.graph import affinity_stats
from repro.data import three_circles, two_moons
from repro.kernels import ops

from .common import csv_row, time_fn

SPECS = (
    ("dense", AffinitySpec(kind="rbf", sigma=0.25)),
    ("knn30", AffinitySpec(kind="rbf", sigma=0.25, knn_k=30)),
    ("ad+knn10", AffinitySpec(kind="rbf", bandwidth="adaptive",
                              scale_k=7, knn_k=10)),
)


def _build(x, spec):
    scale, thr = affinity_stats(x, spec)
    return ops.affinity_and_degree(x, spec=spec, scale_r=scale,
                                   scale_c=scale, thr=thr)


def run(n=1024, moons_n=480, max_iter=400):
    rows = []
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, 2)),
                    jnp.float32)

    # --- two-pass build + per-sweep cost, dense vs truncated -------------
    built = {}
    for tag, spec in SPECS:
        t_build, (a, d) = time_fn(_build, x, spec)
        nnz = float((np.asarray(a) != 0.0).sum(axis=1).mean())
        built[tag] = (a, d)
        rows.append(csv_row(f"affinity/build/{tag}", t_build,
                            f"n={n} nnz_per_row={nnz:.1f}"))
        for r in (1, 4):
            v = jax.random.uniform(jax.random.key(r), (n, r))
            t_sweep, _ = time_fn(
                lambda a=a, v=v, d=d: ops.degree_normalized_matmat(a, v, d))
            rows.append(csv_row(
                f"affinity/sweep/{tag}/r={r}", t_sweep,
                f"nnz_frac={nnz / n:.3f} dense_storage=1"))

    # --- quality: the two_moons acceptance -------------------------------
    xm, ym = two_moons(moons_n, seed=0)
    xmj = jnp.asarray(xm)
    for tag, spec in SPECS:
        cfg = GPICConfig(affinity=spec, max_iter=max_iter, n_vectors=2,
                         embedding="orthogonal")
        t, res = time_fn(run_gpic, xmj, 2, cfg, key=jax.random.key(1))
        ari = adjusted_rand_index(ym, np.asarray(res.labels))
        rows.append(csv_row(
            f"affinity/moons/{tag}", t,
            f"ari={ari:.3f} n_iter={int(res.n_iter)} "
            f"iters={np.asarray(res.n_iter_cols).tolist()}"))

    # --- the subspace residual stopping rule (assert the reduction) ------
    xc, yc = three_circles(moons_n, seed=0)
    xcj = jnp.asarray(xc)
    base = GPICConfig(affinity_kind="rbf", sigma=0.3, max_iter=max_iter,
                      n_vectors=2, embedding="orthogonal")
    t_full, full = time_fn(run_gpic, xcj, 3, base, key=jax.random.key(1))
    t_res, res = time_fn(run_gpic, xcj, 3, base.with_(residual_tol=1e-3),
                         key=jax.random.key(1))
    sweeps_full = int(np.asarray(full.n_iter_cols).max())
    sweeps_res = int(np.asarray(res.n_iter_cols).max())
    assert sweeps_res < sweeps_full, (
        f"residual stopping did not reduce sweeps: {sweeps_res} vs "
        f"{sweeps_full}")
    np.testing.assert_array_equal(
        np.asarray(res.embedding), np.asarray(full.embedding),
        err_msg="residual stopping perturbed the pinned column-0 trajectory")
    ari_res = adjusted_rand_index(yc, np.asarray(res.labels))
    rows.append(csv_row(
        "affinity/residual_stop", t_res,
        f"sweeps={sweeps_res} vs_max_iter={sweeps_full} ari={ari_res:.3f} "
        f"col0_bitwise=1"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
