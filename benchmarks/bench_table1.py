"""Paper Table 1: runtime share of the affinity-matrix stage in serial PIC.

The paper measures 73-99 % (avg 88.6 %) of serial PIC time in the O(n² m)
affinity build on two-moons / three-circles. We reproduce the breakdown at
CPU-feasible n (the paper's MATLAB interpreter overhead is absent here, so
the share depends on m — reported for the paper's m=2 and a 16-d lift).
"""
from __future__ import annotations

import numpy as np

from repro.core import pic_serial_numpy
from repro.data import three_circles, two_moons

from .common import csv_row


def run(sizes=(1000, 2000, 4000), max_iter=3):
    rows = []
    for name, gen in (("two_moons", two_moons), ("three_circles",
                                                 three_circles)):
        xw, _ = gen(64, seed=0)
        pic_serial_numpy(xw, 2, affinity_kind="cosine_shifted", max_iter=2)
        for n in sizes:
            x, _ = gen(n, seed=0)
            for m_lift in (2, 16):
                if m_lift == 2:
                    xl = x
                else:
                    rng = np.random.default_rng(0)
                    xl = x @ rng.standard_normal((2, m_lift)).astype(np.float32)
                _, _, tm = pic_serial_numpy(
                    xl, 2, affinity_kind="cosine_shifted", max_iter=max_iter,
                    return_timings=True)
                frac = tm["affinity_s"] / max(tm["total_s"], 1e-12)
                rows.append(csv_row(
                    f"table1/{name}/n={n}/m={m_lift}", tm["total_s"],
                    f"affinity_frac={frac:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
