"""Paper Experiment II (Figure 5): cluster quality vs balanced subsampling.

Four datasets (cassini, gaussians, shapes, smiley) at n=45,000; subsample
balanced fractions; run GPIC; report mean±std ARI and Jaccard over repeats.
Paper claim: quality shows no significant degradation under subsampling.

The full-n reference uses the matrix-free path (the 45k explicit A would be
8.1 GB); subsamples use the paper-faithful explicit pipeline.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import adjusted_rand_index, gpic, jaccard_index
from repro.data import dataset_by_name
from repro.data.synthetic import subsample_balanced

from .common import csv_row

SIGMAS = {"cassini": 0.3, "gaussians": 0.3, "shapes": 0.3, "smiley": 0.15}
# cassini's two lobes need the multi-vector embedding; smiley's 1-D
# embedding is cleaner without extra random-restart vectors
N_VECTORS = {"cassini": 2, "gaussians": 1, "shapes": 1, "smiley": 1}


def run(n=45_000, fractions=(0.002, 0.005, 0.01, 0.02, 0.05, 0.1),
        repeats=3, max_iter=400):
    rows = []
    for name in ("cassini", "gaussians", "shapes", "smiley"):
        x, y, k = dataset_by_name(name, n, seed=0)
        for frac in fractions:
            aris, jacs = [], []
            for rep in range(repeats):
                xs, ys = subsample_balanced(x, y, frac, seed=rep)
                res = gpic(jnp.asarray(xs), k, key=jax.random.key(rep),
                           affinity_kind="rbf", sigma=SIGMAS[name],
                           max_iter=max_iter, use_pallas=False,
                           n_vectors=N_VECTORS[name])
                lab = np.asarray(res.labels)
                aris.append(adjusted_rand_index(ys, lab))
                jacs.append(jaccard_index(ys, lab))
            rows.append(csv_row(
                f"exp2/{name}/frac={frac}", 0.0,
                f"ari={np.mean(aris):.3f}+-{np.std(aris):.3f} "
                f"jaccard={np.mean(jacs):.3f}+-{np.std(jacs):.3f} "
                f"n_sub={len(ys)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
